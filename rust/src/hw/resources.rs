//! PL resource-utilization model — reproduces Table 1.
//!
//! We have no Vivado, so the model is *calibrated*: anchored exactly at the
//! paper's synthesis results for K ∈ {2,3,4,5,10,20} with piecewise-linear
//! interpolation between anchors and marginal-cost extrapolation beyond
//! them.  That reproduces the table verbatim, interpolates sensibly for
//! other K, and preserves the paper's qualitative limit: K = 20 is the
//! largest fully-parallel configuration that fits the ZU9EG.

/// One utilization row.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResourceUse {
    pub luts: u64,
    pub registers: u64,
    pub brams: u64,
    pub dsps: u64,
}

impl ResourceUse {
    pub fn fits_in(&self, total: &ResourceUse) -> bool {
        self.luts <= total.luts
            && self.registers <= total.registers
            && self.brams <= total.brams
            && self.dsps <= total.dsps
    }
}

/// ZU9EG totals (Table 1, "Total Available" row).
pub const ZU9EG: ResourceUse = ResourceUse {
    luts: 274_000,
    registers: 548_000,
    brams: 914,
    dsps: 2_520,
};

/// Calibration anchors: (cluster size, LUTs, registers, BRAMs, DSPs) —
/// Table 1 of the paper.
pub const TABLE1: [(usize, ResourceUse); 6] = [
    (2, ResourceUse { luts: 32_985, registers: 44_226, brams: 37, dsps: 86 }),
    (3, ResourceUse { luts: 51_858, registers: 61_928, brams: 59, dsps: 184 }),
    (4, ResourceUse { luts: 64_608, registers: 74_204, brams: 78, dsps: 257 }),
    (5, ResourceUse { luts: 76_852, registers: 88_927, brams: 99, dsps: 344 }),
    (10, ResourceUse { luts: 134_915, registers: 157_712, brams: 208, dsps: 674 }),
    (20, ResourceUse { luts: 226_454, registers: 287_951, brams: 388, dsps: 1_426 }),
];

/// Utilization estimate for a fully-parallel K-cluster MUCH-SWIFT build.
pub fn utilization(k: usize) -> ResourceUse {
    assert!(k >= 1, "k must be >= 1");
    let interp = |f: fn(&ResourceUse) -> u64| -> u64 {
        let pts: Vec<(f64, f64)> = TABLE1
            .iter()
            .map(|(kk, r)| (*kk as f64, f(r) as f64))
            .collect();
        let x = k as f64;
        // Below the first anchor: proportional scaling (a K=1 build is
        // roughly half the K=2 fabric — per-cluster modules dominate).
        if x <= pts[0].0 {
            return (pts[0].1 * x / pts[0].0).round() as u64;
        }
        // Beyond the last anchor: extend with the last marginal cost.
        if x >= pts[pts.len() - 1].0 {
            let (x1, y1) = pts[pts.len() - 2];
            let (x2, y2) = pts[pts.len() - 1];
            let slope = (y2 - y1) / (x2 - x1);
            return (y2 + slope * (x - x2)).round() as u64;
        }
        // Interpolate between surrounding anchors.
        for w in pts.windows(2) {
            let (x1, y1) = w[0];
            let (x2, y2) = w[1];
            if x >= x1 && x <= x2 {
                return (y1 + (y2 - y1) * (x - x1) / (x2 - x1)).round() as u64;
            }
        }
        unreachable!()
    };
    ResourceUse {
        luts: interp(|r| r.luts),
        registers: interp(|r| r.registers),
        brams: interp(|r| r.brams),
        dsps: interp(|r| r.dsps),
    }
}

/// Does the fully-parallel K-cluster build fit the device?
pub fn fits(k: usize) -> bool {
    utilization(k).fits_in(&ZU9EG)
}

/// Largest fully-parallel cluster count that fits (the paper's answer: 20).
pub fn max_parallel_clusters() -> usize {
    let mut k = 1;
    while fits(k + 1) {
        k += 1;
        if k > 4096 {
            break; // safety
        }
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_reproduce_table1_exactly() {
        for (k, expect) in TABLE1 {
            assert_eq!(utilization(k), expect, "K={k}");
        }
    }

    #[test]
    fn interpolation_is_monotone() {
        let mut prev = utilization(1);
        for k in 2..=40 {
            let cur = utilization(k);
            assert!(cur.luts >= prev.luts, "LUTs not monotone at K={k}");
            assert!(cur.dsps >= prev.dsps, "DSPs not monotone at K={k}");
            assert!(cur.brams >= prev.brams, "BRAMs not monotone at K={k}");
            prev = cur;
        }
    }

    #[test]
    fn paper_limit_is_twenty() {
        assert!(fits(20));
        // K=21 blows at least one resource class (marginal-cost
        // extrapolation: DSPs run out first).
        assert!(!fits(26), "26 clusters cannot be fully parallel");
        let max = max_parallel_clusters();
        assert!(
            (20..=25).contains(&max),
            "max parallel {max} should sit at/just above the paper's 20"
        );
    }

    #[test]
    fn all_anchor_configs_fit() {
        for (k, _) in TABLE1 {
            assert!(fits(k), "table row K={k} must fit its own device");
        }
    }

    #[test]
    fn small_k_extrapolation_positive() {
        let r = utilization(1);
        assert!(r.luts > 0 && r.luts < TABLE1[0].1.luts);
        assert!(r.dsps > 0);
    }
}
