//! Bandwidth × latency channels: the TLM abstraction for PCIe, the AXI
//! PS<->PL port and the DDR3 controller port.
//!
//! A [`Link`] is a serially-reusable resource: transfers queue behind each
//! other (`busy_until`), each costing `latency + bytes/bandwidth`.  This is
//! the standard "simple bus" TLM — enough to capture the contention and
//! store-and-forward effects the paper's DMA design addresses, while
//! burst-level interleaving is handled by `stream`.

use super::{secs_to_ps, Time};

/// A point-to-point channel with fixed bandwidth and per-transfer latency.
#[derive(Clone, Debug)]
pub struct Link {
    pub name: &'static str,
    bytes_per_s: f64,
    latency_ps: Time,
    busy_until: Time,
    /// Total bytes carried (for utilization reports).
    pub bytes_carried: u64,
    /// Total time spent actually transferring.
    pub busy_ps: Time,
}

impl Link {
    pub fn new(name: &'static str, bytes_per_s: f64, latency_s: f64) -> Self {
        assert!(bytes_per_s > 0.0);
        Self {
            name,
            bytes_per_s,
            latency_ps: secs_to_ps(latency_s),
            busy_until: 0,
            bytes_carried: 0,
            busy_ps: 0,
        }
    }

    /// Pure cost of moving `bytes` (no queueing).
    #[inline]
    pub fn transfer_ps(&self, bytes: u64) -> Time {
        self.latency_ps + secs_to_ps(bytes as f64 / self.bytes_per_s)
    }

    /// Request a transfer that may start no earlier than `earliest`;
    /// returns `(start, end)` after queueing behind in-flight traffic.
    pub fn request(&mut self, earliest: Time, bytes: u64) -> (Time, Time) {
        let start = earliest.max(self.busy_until);
        let dur = self.transfer_ps(bytes);
        let end = start + dur;
        self.busy_until = end;
        self.bytes_carried += bytes;
        self.busy_ps += dur;
        (start, end)
    }

    /// When the link frees up.
    #[inline]
    pub fn busy_until(&self) -> Time {
        self.busy_until
    }

    /// Fraction of `[0, horizon]` spent transferring.
    pub fn utilization(&self, horizon: Time) -> f64 {
        if horizon == 0 {
            0.0
        } else {
            self.busy_ps as f64 / horizon as f64
        }
    }

    pub fn reset(&mut self) {
        self.busy_until = 0;
        self.bytes_carried = 0;
        self.busy_ps = 0;
    }

    #[inline]
    pub fn bytes_per_s(&self) -> f64 {
        self.bytes_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_is_latency_plus_bandwidth() {
        // 1 GB/s, 1 µs latency: 1 MB costs 1µs + 1ms.
        let l = Link::new("pcie", 1e9, 1e-6);
        let ps = l.transfer_ps(1_000_000);
        assert_eq!(ps, 1_000_000 + 1_000_000_000);
    }

    #[test]
    fn queueing_serializes() {
        let mut l = Link::new("axi", 1e9, 0.0);
        let (s1, e1) = l.request(0, 1000); // 1 µs
        let (s2, e2) = l.request(0, 1000); // queues behind
        assert_eq!(s1, 0);
        assert_eq!(e1, 1_000_000);
        assert_eq!(s2, e1);
        assert_eq!(e2, 2_000_000);
        // A later-arriving request starts at its arrival.
        let (s3, _) = l.request(10_000_000, 10);
        assert_eq!(s3, 10_000_000);
    }

    #[test]
    fn utilization_and_reset() {
        let mut l = Link::new("ddr", 2e9, 0.0);
        l.request(0, 2_000); // 1 µs busy
        assert!((l.utilization(2_000_000) - 0.5).abs() < 1e-9);
        assert_eq!(l.bytes_carried, 2000);
        l.reset();
        assert_eq!(l.busy_until(), 0);
        assert_eq!(l.bytes_carried, 0);
    }
}
