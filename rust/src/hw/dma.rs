//! Descriptor-based DMA engine: the custom PCIe→DDR3 path one Cortex-R5
//! manages in MUCH-SWIFT (section 4, item (1)).
//!
//! The host payload is split into descriptors; for each, the R5 spends
//! setup cycles, then the payload crosses the PCIe link and is written
//! through the 64-bit AXI DMA channel into DDR3.  Descriptor setup for
//! burst *i+1* overlaps the transfer of burst *i* (that is the point of a
//! descriptor ring), so the steady state is bandwidth-limited by the
//! slower of PCIe and the DDR3 write port.

use super::clock::ClockDomain;
use super::link::Link;
use super::Time;
use crate::config::PlatformConfig;

/// Default descriptor payload (256 KiB — typical scatter-gather size).
pub const DESCRIPTOR_BYTES: u64 = 256 * 1024;

/// R5 cycles to prepare one descriptor (register writes + cache ops).
pub const DESC_SETUP_CYCLES: u64 = 400;

/// Outcome of one host→DDR3 ingest.
#[derive(Clone, Debug, PartialEq)]
pub struct DmaReport {
    pub finish_ps: Time,
    pub descriptors: u64,
    pub pcie_util: f64,
    pub ddr3_util: f64,
}

/// DMA engine over two [`Link`]s and the R5 control clock.
#[derive(Clone, Debug)]
pub struct DmaEngine {
    pcie: Link,
    ddr3_write: Link,
    r5: ClockDomain,
}

impl DmaEngine {
    pub fn new(cfg: &PlatformConfig) -> Self {
        Self {
            pcie: Link::new("pcie", cfg.pcie_bytes_per_s, cfg.pcie_setup_s),
            // The DMA channel into DDR3 is the 64-bit AXI port; it cannot
            // exceed the DDR3 sustained rate either.
            ddr3_write: Link::new(
                "ddr3-wr",
                (cfg.axi_dma_bytes as f64 * cfg.pl_freq_hz).min(cfg.ddr3_sustained()),
                cfg.ddr3_latency_s,
            ),
            r5: ClockDomain::new(if cfg.r5_freq_hz > 0.0 {
                cfg.r5_freq_hz
            } else {
                // Platforms without an R5 (single-core baselines) pay the
                // setup on their main core; modelling it at A53 speed.
                cfg.a53_freq_hz
            }),
        }
    }

    /// Move `bytes` host→DDR3. Returns the report; engine state (link
    /// queues) persists so back-to-back ingests queue realistically.
    pub fn ingest(&mut self, start: Time, bytes: u64) -> DmaReport {
        if bytes == 0 {
            return DmaReport {
                finish_ps: start,
                descriptors: 0,
                pcie_util: 0.0,
                ddr3_util: 0.0,
            };
        }
        let descriptors = bytes.div_ceil(DESCRIPTOR_BYTES);
        let setup = self.r5.cycles_to_ps(DESC_SETUP_CYCLES);
        let mut finish = start;
        // First descriptor's setup is exposed; the rest overlap transfers.
        let mut ready = start + setup;
        for i in 0..descriptors {
            let sz = if i + 1 == descriptors {
                bytes - (descriptors - 1) * DESCRIPTOR_BYTES
            } else {
                DESCRIPTOR_BYTES
            };
            let (_, pcie_done) = self.pcie.request(ready, sz);
            let (_, ddr_done) = self.ddr3_write.request(pcie_done, sz);
            finish = ddr_done;
            // Next descriptor was prepared during this transfer.
            ready = ready.max(start) + 0;
        }
        DmaReport {
            finish_ps: finish,
            descriptors,
            pcie_util: self.pcie.utilization(finish.max(1)),
            ddr3_util: self.ddr3_write.utilization(finish.max(1)),
        }
    }

    /// Pure-bandwidth lower bound (for tests/reports).
    pub fn ideal_ps(&self, bytes: u64) -> Time {
        self.pcie.transfer_ps(bytes).max(self.ddr3_write.transfer_ps(bytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PlatformConfig {
        PlatformConfig::zcu102()
    }

    #[test]
    fn ingest_is_pcie_bound_on_zcu102() {
        // PCIe 1.6 GB/s < DDR3 write port: PCIe limits.
        let mut dma = DmaEngine::new(&cfg());
        let bytes = 64 * 1024 * 1024;
        let r = dma.ingest(0, bytes);
        let ideal = (bytes as f64 / 1.6e9) * 1e12;
        assert!(r.finish_ps as f64 > ideal);
        // Within 15% of wire speed (setup/latency amortized over 256
        // descriptors).
        assert!(
            (r.finish_ps as f64) < ideal * 1.15,
            "finish {} vs ideal {ideal}",
            r.finish_ps
        );
        assert_eq!(r.descriptors, 256);
        assert!(r.pcie_util > 0.8, "pcie util {}", r.pcie_util);
    }

    #[test]
    fn small_transfer_dominated_by_setup() {
        let mut dma = DmaEngine::new(&cfg());
        let r = dma.ingest(0, 512);
        // 5 µs PCIe setup + R5 descriptor prep dominate the sub-µs payload.
        assert!(r.finish_ps > 5_000_000, "finish {}", r.finish_ps);
        assert_eq!(r.descriptors, 1);
    }

    #[test]
    fn zero_bytes_no_op() {
        let mut dma = DmaEngine::new(&cfg());
        let r = dma.ingest(42, 0);
        assert_eq!(r.finish_ps, 42);
        assert_eq!(r.descriptors, 0);
    }

    #[test]
    fn back_to_back_ingests_queue() {
        let mut dma = DmaEngine::new(&cfg());
        let a = dma.ingest(0, 1 << 20);
        let b = dma.ingest(0, 1 << 20);
        assert!(b.finish_ps > a.finish_ps, "second ingest must queue");
    }

    #[test]
    fn ideal_bound_holds() {
        let mut dma = DmaEngine::new(&cfg());
        let bytes = 8 << 20;
        let ideal = dma.ideal_ps(bytes);
        let r = dma.ingest(0, bytes);
        assert!(r.finish_ps >= ideal);
    }
}
