//! Discrete-event simulation core: a time-ordered event queue with
//! deterministic FIFO tie-breaking.
//!
//! The TLM components (`stream`, `dma`) drive their burst-level state
//! machines off this queue; the coarser per-phase cost models (`pl`,
//! `zynq`) do closed-form accounting and only use the queue where
//! interleaving actually matters (producer/consumer overlap with finite
//! buffering).

use super::Time;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// A pending event: fires at `time` carrying a caller-defined payload.
#[derive(Clone, Debug, PartialEq, Eq)]
struct Entry<K> {
    time: Time,
    seq: u64,
    kind: K,
}

impl<K: Eq> Ord for Entry<K> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Min-heap via Reverse at the queue level; order by (time, seq) so
        // same-time events fire in insertion order (determinism).
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

impl<K: Eq> PartialOrd for Entry<K> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Time-ordered event queue.
#[derive(Debug)]
pub struct EventQueue<K: Eq> {
    heap: BinaryHeap<Reverse<Entry<K>>>,
    seq: u64,
    now: Time,
}

impl<K: Eq> Default for EventQueue<K> {
    fn default() -> Self {
        Self::new()
    }
}

impl<K: Eq> EventQueue<K> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0,
        }
    }

    /// Current simulation time (time of the last popped event).
    #[inline]
    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `kind` at absolute time `at` (>= now).
    pub fn schedule(&mut self, at: Time, kind: K) {
        debug_assert!(
            at >= self.now,
            "cannot schedule into the past: {at} < {}",
            self.now
        );
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Entry {
            time: at.max(self.now),
            seq,
            kind,
        }));
    }

    /// Schedule `kind` `delay` after now.
    pub fn schedule_in(&mut self, delay: Time, kind: K) {
        self.schedule(self.now + delay, kind);
    }

    /// Pop the next event, advancing `now`.
    pub fn pop(&mut self) -> Option<(Time, K)> {
        self.heap.pop().map(|Reverse(e)| {
            self.now = e.time;
            (e.time, e.kind)
        })
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq, Eq)]
    enum Ev {
        A(u32),
        B,
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(30, Ev::A(3));
        q.schedule(10, Ev::A(1));
        q.schedule(20, Ev::B);
        assert_eq!(q.pop(), Some((10, Ev::A(1))));
        assert_eq!(q.now(), 10);
        assert_eq!(q.pop(), Some((20, Ev::B)));
        assert_eq!(q.pop(), Some((30, Ev::A(3))));
        assert!(q.pop().is_none());
    }

    #[test]
    fn same_time_is_fifo() {
        let mut q = EventQueue::new();
        q.schedule(5, Ev::A(1));
        q.schedule(5, Ev::A(2));
        q.schedule(5, Ev::A(3));
        assert_eq!(q.pop().unwrap().1, Ev::A(1));
        assert_eq!(q.pop().unwrap().1, Ev::A(2));
        assert_eq!(q.pop().unwrap().1, Ev::A(3));
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule(100, Ev::B);
        q.pop();
        q.schedule_in(50, Ev::A(0));
        assert_eq!(q.pop(), Some((150, Ev::A(0))));
    }

    #[test]
    fn deterministic_replay() {
        let run = || {
            let mut q = EventQueue::new();
            for i in 0..100u32 {
                q.schedule(((i * 7) % 13) as Time, Ev::A(i));
            }
            let mut order = Vec::new();
            while let Some((t, Ev::A(i))) = q.pop() {
                order.push((t, i));
            }
            order
        };
        assert_eq!(run(), run());
    }
}
