//! # muchswift — MUCH-SWIFT reproduction
//!
//! A full-system reproduction of *"Using Multi-Core HW/SW Co-design
//! Architecture for Accelerating K-means Clustering Algorithm"* (Kamali,
//! 2018): the kd-tree filtering algorithm, the two-level 4-way parallel
//! clustering scheme, and a transaction-level simulator of the paper's
//! Zynq UltraScale+ platform, with the distance/compare/update arithmetic
//! offloaded to AOT-compiled JAX/Pallas kernels executed through PJRT
//! (the `xla` crate) — Python never runs at request time.
//!
//! Layering (see DESIGN.md at the repo root):
//! - `util`, `config`, `data` — substrates (offline toolchain gaps included)
//! - `kdtree`, `kmeans` — the algorithms (Alg. 1 / Alg. 2 + baselines),
//!   fronted by the unified solver API (`kmeans::solver`): one
//!   `KmeansSpec`, one `Solver` trait, pluggable `PanelBackend`s and
//!   per-iteration `IterObserver`s across all four engines
//! - `kmeans::shard` — the shard plane: P-way `ShardPlan` partitioning +
//!   hierarchical count-weighted combine under every two-level path
//!   (`KmeansSpec::shards(P)`; the paper's quartet is P = 4)
//! - `hw` — the ZCU102 platform model (clock domains, DMA, DDR3, BRAM, PL)
//! - `runtime` — PJRT artifact loading & execution (the "PL" compute)
//! - `coordinator` — the deployable system: leader + P shard workers +
//!   offload
//! - `serve` — the online half of the fit/predict split: `KmeansModel`
//!   artifacts (`kmeans::model`), batched inference (`kmeans::predict`)
//!   and the micro-batching `ClusterService`
//! - `arch` — the paper's comparison architectures as cost models
//! - `experiments` — regenerates every figure/table of the evaluation

pub mod config;
pub mod data;
pub mod kdtree;
pub mod kmeans;
pub mod util;
pub mod hw;
pub mod runtime;
pub mod coordinator;
pub mod serve;
pub mod arch;
pub mod experiments;
