//! The unified-solver acceptance suite:
//!
//! 1. Cross-solver golden test — every [`Algo`] variant, driven through
//!    the same [`KmeansSpec`]/[`SolverCtx`], must reach the Lloyd
//!    objective on a planted well-separated dataset, and its `RunStats`
//!    totals must be non-zero for exactly the counters that algorithm is
//!    documented to charge.
//! 2. CLI round trip — `muchswift cluster --algo <variant>` end-to-end on
//!    synthetic data for every variant, plus negative paths.

use muchswift::data::synthetic::generate_params;
use muchswift::kmeans::init::Init;
use muchswift::kmeans::solver::{Algo, KmeansSpec, SolverCtx};
use muchswift::kmeans::Metric;
use std::process::Command;

#[test]
fn all_algos_reach_lloyd_objective_with_documented_counters() {
    // Planted, well-separated clusters: every exact-or-better strategy
    // must land on the same (global) optimum.
    let s = generate_params(4000, 3, 5, 0.05, 5.0, 17);
    let base = KmeansSpec::new(5)
        .metric(Metric::Euclid)
        .init(Init::KmeansPlusPlus)
        .seed(9);

    // One ctx for the whole sweep: the kd-tree is built once and shared
    // across the tree-based solvers.
    let mut ctx = SolverCtx::new(&s.data);
    let lloyd = base.clone().algo(Algo::Lloyd).solve(&mut ctx);
    assert!(lloyd.stats.converged);
    let obj_lloyd = lloyd.objective(&s.data, Metric::Euclid);

    for &algo in Algo::all() {
        let r = base.clone().algo(algo).solve(&mut ctx);
        assert!(r.stats.converged, "{algo:?} did not converge");
        assert_eq!(r.assignments.len(), 4000, "{algo:?}");
        assert_eq!(r.sizes().iter().sum::<usize>(), 4000, "{algo:?}");

        let obj = r.objective(&s.data, Metric::Euclid);
        assert!(
            (obj - obj_lloyd).abs() <= 1e-3 * (1.0 + obj_lloyd.abs()),
            "{algo:?} objective {obj} vs lloyd {obj_lloyd}"
        );

        // Counter golden rules: each algorithm charges exactly the work
        // its docs say it does.
        let st = &r.stats;
        assert!(st.total_dist_evals() > 0, "{algo:?}: no distance work");
        match algo {
            Algo::Lloyd | Algo::Elkan => {
                // Flat passes over the points; no tree bookkeeping.
                assert!(st.total_leaf_points() > 0, "{algo:?}");
                assert_eq!(st.total_node_visits(), 0, "{algo:?}");
                assert_eq!(st.total_prune_tests(), 0, "{algo:?}");
                assert_eq!(st.total_interior_assigns(), 0, "{algo:?}");
            }
            Algo::Filter | Algo::FilterBatched => {
                assert!(st.total_node_visits() > 0, "{algo:?}");
                assert!(st.total_prune_tests() > 0, "{algo:?}");
                // With tight planted clusters most mass is assigned
                // wholesale at pruned interior nodes.
                assert!(st.total_interior_assigns() > 0, "{algo:?}");
            }
            Algo::TwoLevel => {
                // The result's own stats are the level-2 refinement's
                // (tree-based), and the extension carries per-quarter
                // level-1 work.
                assert!(st.total_node_visits() > 0, "{algo:?}");
                let ext = r.ext.two_level.as_ref().expect("two-level ext");
                assert_eq!(ext.quarter_sizes.iter().sum::<usize>(), 4000);
                for (qi, l1) in ext.level1_stats.iter().enumerate() {
                    assert!(
                        l1.total_dist_evals() > 0,
                        "quarter {qi} did no level-1 work"
                    );
                    assert!(l1.total_node_visits() > 0, "quarter {qi}");
                }
            }
        }
        // Lloyd does exactly n*k evals per iteration; every pruning
        // strategy must beat that on this dataset.
        if algo != Algo::Lloyd {
            let lloyd_equiv = 4000u64 * 5 * st.iterations() as u64;
            assert!(
                st.total_dist_evals() < lloyd_equiv,
                "{algo:?} did not prune: {} >= {lloyd_equiv}",
                st.total_dist_evals()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// CLI round trip
// ---------------------------------------------------------------------------

fn cluster_cmd(extra: &[&str]) -> std::process::Output {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_muchswift"));
    cmd.args([
        "cluster",
        "--backend",
        "cpu",
        "--n",
        "2000",
        "--d",
        "3",
        "--k",
        "4",
        "--sigma",
        "0.05",
        "--seed",
        "7",
        "--max-iters",
        "80",
        "--tol",
        "1e-6",
        "--workers",
        "2",
    ]);
    cmd.args(extra);
    cmd.output().expect("failed to spawn muchswift binary")
}

#[test]
fn cli_cluster_round_trips_every_algo() {
    for algo in ["lloyd", "elkan", "filter", "filter-batched", "two-level"] {
        let out = cluster_cmd(&["--algo", algo]);
        let stdout = String::from_utf8_lossy(&out.stdout);
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(
            out.status.success(),
            "--algo {algo} failed\nstdout: {stdout}\nstderr: {stderr}"
        );
        assert!(stdout.contains("converged: true"), "--algo {algo}: {stdout}");
        assert!(stdout.contains("objective:"), "--algo {algo}: {stdout}");
        assert!(stdout.contains("dist evals"), "--algo {algo}: {stdout}");
    }
}

#[test]
fn cli_cluster_trace_streams_iterations() {
    let out = cluster_cmd(&["--algo", "filter", "--trace"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "{stdout}");
    assert!(stdout.contains("[Main] iter"), "no observer lines: {stdout}");
    // --trace on two-level streams the phase structure too.
    let out = cluster_cmd(&["--algo", "two-level", "--trace"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "{stdout}");
    assert!(
        stdout.contains("Level1") && stdout.contains("[Level2] iter"),
        "no phased observer lines: {stdout}"
    );
}

#[test]
fn cli_cluster_shards_round_trip_and_range_checks() {
    // A non-default shard count drives the coordinator end to end.
    let out = cluster_cmd(&["--algo", "two-level", "--shards", "8"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout: {stdout}\nstderr: {stderr}");
    assert!(
        stdout.contains("level-1 iterations per shard (8)"),
        "{stdout}"
    );
    assert!(stdout.contains("8 shards"), "coordinator metrics: {stdout}");

    // P = 0 is rejected before any work happens.
    let out = cluster_cmd(&["--algo", "two-level", "--shards", "0"]);
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--shards must be >= 1"), "{stderr}");

    // P > n is rejected with both numbers in the message (n=2000 here).
    let out = cluster_cmd(&["--algo", "two-level", "--shards", "2001"]);
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("--shards 2001 exceeds the dataset size n=2000"),
        "{stderr}"
    );

    // The fit surface shares the same validation.
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_muchswift"));
    let out = cmd
        .args(["fit", "--n", "500", "--d", "2", "--k", "3", "--shards", "501"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("exceeds the dataset size"), "{stderr}");
}

#[test]
fn cli_cluster_rejects_unknown_algo_and_backend() {
    let out = cluster_cmd(&["--algo", "bogus"]);
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown algo"), "{stderr}");

    let mut cmd = Command::new(env!("CARGO_BIN_EXE_muchswift"));
    let out = cmd
        .args(["cluster", "--backend", "quantum"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown backend"), "{stderr}");
}
