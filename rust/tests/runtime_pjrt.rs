//! Integration: the AOT artifacts load through PJRT and compute the same
//! numbers as the Rust reference implementations — the L1/L2/L3 seam.
//!
//! Requires `make artifacts` and a real PJRT-backed `xla` crate; each test
//! skips with a clear message otherwise (the offline workspace builds
//! against the `xla` stub, where artifact loading always fails).

use muchswift::data::synthetic::generate_params;
use muchswift::data::Dataset;
use muchswift::kdtree::KdTree;
use muchswift::kmeans::filtering::{self, CpuPanels, FilterOpts};
use muchswift::kmeans::init::{init_centroids, Init};
use muchswift::kmeans::metrics::{self, Metric};
use muchswift::kmeans::panel::{PanelJobs, PanelSet};
use muchswift::runtime::{PjrtPanels, PjrtRuntime};
use std::path::PathBuf;
use std::sync::OnceLock;

fn artifact_dir() -> PathBuf {
    let mut dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    dir.push("artifacts");
    dir
}

/// `None` (with a skip notice) when the runtime cannot load — missing
/// artifacts or the stub `xla` backend.  Real-hardware CI must export
/// `MUCHSWIFT_REQUIRE_PJRT=1` so a genuine load regression fails the
/// suite instead of silently skipping it.
fn runtime() -> Option<&'static PjrtRuntime> {
    static RT: OnceLock<Option<PjrtRuntime>> = OnceLock::new();
    RT.get_or_init(|| match PjrtRuntime::load(&artifact_dir()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            if std::env::var_os("MUCHSWIFT_REQUIRE_PJRT").is_some() {
                panic!("MUCHSWIFT_REQUIRE_PJRT is set but the PJRT runtime failed to load: {e}");
            }
            eprintln!("skipping pjrt tests: {e}");
            None
        }
    })
    .as_ref()
}

#[test]
fn lloyd_step_matches_rust_reference() {
    let Some(rt) = runtime() else { return };
    for (metric, n, d, k) in [
        (Metric::Euclid, 1500, 3, 5),
        (Metric::Euclid, 1024, 15, 20),
        (Metric::Euclid, 300, 15, 100),
        (Metric::Euclid, 512, 33, 6),
        (Metric::Manhattan, 700, 3, 5),
        (Metric::Manhattan, 700, 15, 20),
    ] {
        let s = generate_params(n, d, k, 0.3, 1.0, 99);
        let init = init_centroids(&s.data, k, Init::UniformSample, metric, 7);
        let out = rt.lloyd_step(&s.data, &init, metric).unwrap();

        // Reference: plain Rust assignment + accumulation.
        let mut sums = vec![0f32; k * d];
        let mut counts = vec![0f32; k];
        let mut cost = 0f64;
        for (i, p) in s.data.iter().enumerate() {
            let (best, bd) = metrics::nearest(metric, p, init.flat(), k, d);
            assert_eq!(
                out.assignments[i], best as i32,
                "assignment mismatch at point {i} ({metric:?} n={n} d={d} k={k})"
            );
            for j in 0..d {
                sums[best * d + j] += p[j];
            }
            counts[best] += 1.0;
            cost += bd as f64;
        }
        assert_eq!(out.counts, counts, "counts ({metric:?} d={d} k={k})");
        for (a, b) in out.sums.iter().zip(sums.iter()) {
            assert!(
                (a - b).abs() < 2e-2 * (1.0 + b.abs()),
                "sums: {a} vs {b} ({metric:?} d={d} k={k})"
            );
        }
        assert!(
            (out.cost as f64 - cost).abs() < 2e-3 * (1.0 + cost.abs()),
            "cost: {} vs {cost}",
            out.cost
        );
    }
}

#[test]
fn filter_panels_match_cpu() {
    let Some(rt) = runtime() else { return };
    let s = generate_params(200, 15, 4, 0.3, 1.0, 5);
    let cents = init_centroids(&s.data, 24, Init::UniformSample, Metric::Euclid, 3);
    // Ragged candidate sets, job count not a multiple of the block.
    let jobs_n = 301usize;
    let d = 15;
    let mut jobs = PanelJobs::new();
    jobs.clear(d);
    for j in 0..jobs_n {
        let len = 1 + (j % 24);
        let cands: Vec<u32> = (0..len as u32).collect();
        jobs.push(s.data.point(j % s.data.len()), &cands);
    }
    let mut got = PanelSet::new();
    rt.filter_panels(&jobs, &cents, Metric::Euclid, &mut got)
        .unwrap();
    assert_eq!(got.len(), jobs_n);
    for j in 0..jobs_n {
        assert_eq!(got.row(j).len(), jobs.cands(j).len());
        let q = jobs.mid(j);
        for (slot, &c) in jobs.cands(j).iter().enumerate() {
            let want = Metric::Euclid.dist(q, cents.point(c as usize));
            let have = got.row(j)[slot];
            assert!(
                (have - want).abs() < 1e-2 * (1.0 + want.abs()),
                "job {j} cand {c}: {have} vs {want}"
            );
        }
    }
}

#[test]
fn batched_filtering_through_pjrt_matches_cpu_run() {
    let Some(rt) = runtime() else { return };
    let s = generate_params(900, 3, 6, 0.2, 1.0, 11);
    let tree = KdTree::build(&s.data);
    let init = init_centroids(&s.data, 6, Init::UniformSample, Metric::Euclid, 2);
    let opts = FilterOpts { metric: Metric::Euclid, tol: 1e-6, max_iters: 15 };

    let cpu = filtering::run_batched(&s.data, &tree, &init, &opts, &mut CpuPanels);
    let mut panels = PjrtPanels::new(rt);
    let hw = filtering::run_batched(&s.data, &tree, &init, &opts, &mut panels);

    assert!(panels.jobs_offloaded > 0, "offload path must actually run");
    // XLA math (interpret-mode Pallas) vs Rust f32: same formulae, ulp-level
    // differences allowed; trajectories must agree.
    for (ca, cb) in cpu.centroids.iter().zip(hw.centroids.iter()) {
        for (x, y) in ca.iter().zip(cb.iter()) {
            assert!((x - y).abs() < 5e-3, "centroid drift: {x} vs {y}");
        }
    }
    let same = cpu
        .assignments
        .iter()
        .zip(hw.assignments.iter())
        .filter(|(a, b)| a == b)
        .count();
    assert!(same as f64 >= 0.99 * 900.0, "assignments: {same}/900 agree");
}

#[test]
fn oversized_request_fails_cleanly() {
    let Some(rt) = runtime() else { return };
    let data = Dataset::zeros(8, 200); // d=200 exceeds every artifact
    let cents = Dataset::zeros(2, 200);
    let err = rt.lloyd_step(&data, &cents, Metric::Euclid).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("no artifact"), "unexpected error: {msg}");
}

#[test]
fn runtime_stats_accumulate() {
    let Some(rt) = runtime() else { return };
    let before = rt.stats.executions();
    let s = generate_params(2500, 3, 4, 0.3, 1.0, 1);
    let init = init_centroids(&s.data, 4, Init::UniformSample, Metric::Euclid, 1);
    rt.lloyd_step(&s.data, &init, Metric::Euclid).unwrap();
    // 2500 points / 1024 block = 3 executions, last one padded.
    assert_eq!(rt.stats.executions() - before, 3);
    assert!(rt.stats.exec_seconds() > 0.0);
    assert!(rt.stats.blocks_padded.load(std::sync::atomic::Ordering::Relaxed) >= 1);
}
