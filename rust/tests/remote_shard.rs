//! Remote shard plane integration suite.
//!
//! The headline pin: a loopback remote run of P = 4 is **byte-identical**
//! (centroids and assignments) to the in-process shard plane — the wire
//! carries exact f32 bits and both sides run the one canonical shard
//! solve.  Around it: wire-death fallback semantics, protocol robustness
//! against skewed/hostile peers, and the `shard-worker` binary lifecycle.

use muchswift::coordinator::{Backend, Coordinator};
use muchswift::data::synthetic::generate_params;
use muchswift::kmeans::panel::CpuPanels;
use muchswift::kmeans::remote::protocol::{Message, ERR_VERSION_SKEW, PROTOCOL_VERSION};
use muchswift::kmeans::remote::{self, RemoteShardPool, RemoteWorker, WorkerServer};
use muchswift::kmeans::shard::{level1_spec, solve_level1_shard};
use muchswift::kmeans::solver::{IterLog, KmeansSpec};
use muchswift::kmeans::KmeansResult;
use muchswift::util::fault::{ChaosProxy, FaultSchedule};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::process::{Command, Stdio};

fn assert_bitwise_equal(a: &KmeansResult, b: &KmeansResult) {
    assert_eq!(a.centroids.len(), b.centroids.len());
    for (x, y) in a.centroids.flat().iter().zip(b.centroids.flat()) {
        assert_eq!(x.to_bits(), y.to_bits(), "centroid bits diverged");
    }
    assert_eq!(a.assignments, b.assignments, "assignments diverged");
}

#[test]
fn loopback_p4_remote_run_is_bitwise_identical_to_in_process() {
    let s = generate_params(6000, 3, 5, 0.15, 2.0, 33);
    let spec = KmeansSpec::two_level(5).seed(9).shards(4).workers(4);

    // In-process baseline.
    let local = Coordinator::new(Backend::Cpu).run(&s.data, &spec);

    // Two loopback workers, two connections each: with four remote
    // executors for four shards, zero local pullers spawn, so every
    // level-1 solve provably crossed the wire.
    let w1 = WorkerServer::spawn("127.0.0.1:0").unwrap();
    let w2 = WorkerServer::spawn("127.0.0.1:0").unwrap();
    let (a1, a2) = (w1.addr().to_string(), w2.addr().to_string());
    let pool = RemoteShardPool::new(vec![a1.clone(), a2.clone(), a1, a2]);
    let remote = Coordinator::new(Backend::Cpu)
        .with_remotes(pool)
        .run(&s.data, &spec);

    assert_bitwise_equal(&remote.result, &local.result);
    // The two-level extension travels intact too: per-shard stats and
    // the merged level-2 seed.
    let le = local.result.ext.two_level.as_ref().unwrap();
    let re = remote.result.ext.two_level.as_ref().unwrap();
    assert_eq!(re.quarter_sizes, le.quarter_sizes);
    assert_eq!(
        re.level1_stats.iter().map(|st| st.iterations()).collect::<Vec<_>>(),
        le.level1_stats.iter().map(|st| st.iterations()).collect::<Vec<_>>(),
    );
    assert_eq!(
        re.level1_stats.iter().map(|st| st.total_dist_evals()).collect::<Vec<_>>(),
        le.level1_stats.iter().map(|st| st.total_dist_evals()).collect::<Vec<_>>(),
    );
    for (x, y) in re
        .merged_centroids
        .flat()
        .iter()
        .zip(le.merged_centroids.flat())
    {
        assert_eq!(x.to_bits(), y.to_bits(), "merged seed bits diverged");
    }
    // Accounting: all four shards went remote, nothing fell back, and
    // the wire saw real traffic both ways.
    assert_eq!(remote.metrics.remote_workers, 4);
    assert_eq!(remote.metrics.remote_shards, 4);
    assert_eq!(remote.metrics.remote_fallbacks, 0);
    assert!(remote.metrics.remote_bytes_tx > 0);
    assert!(remote.metrics.remote_bytes_rx > 0);
    // The iteration frames streamed the same live counters the local
    // observers would have.
    assert_eq!(remote.metrics.shard_iters, local.metrics.shard_iters);
    assert_eq!(remote.metrics.shard_dist_evals, local.metrics.shard_dist_evals);
    assert_eq!(remote.metrics.observed_iters, local.metrics.observed_iters);
    // All-local runs report a zeroed remote section.
    assert_eq!(local.metrics.remote_workers, 0);
    assert_eq!(local.metrics.remote_shards, 0);

    w1.shutdown().unwrap();
    w2.shutdown().unwrap();
}

#[test]
fn remote_solve_matches_local_solve_bitwise_and_streams_iterations() {
    let s = generate_params(1200, 3, 4, 0.2, 1.0, 11);
    let base = KmeansSpec::two_level(4).seed(5);
    let wspec = level1_spec(&base, 0);
    let local = solve_level1_shard(&s.data, &wspec, CpuPanels, None::<IterLog>);

    let w = WorkerServer::spawn("127.0.0.1:0").unwrap();
    let mut rw = RemoteWorker::connect(&w.addr().to_string()).unwrap();
    let (mut iters, mut evals) = (0u64, 0u64);
    let partial = rw
        .solve(0, &s.data, &wspec, &mut |st| {
            iters += 1;
            evals += st.dist_evals;
        })
        .unwrap();
    for (x, y) in partial.centroids.flat().iter().zip(local.centroids.flat()) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    assert_eq!(partial.counts, local.sizes());
    assert_eq!(partial.stats.iterations(), local.stats.iterations());
    assert_eq!(partial.stats.total_dist_evals(), local.stats.total_dist_evals());
    assert_eq!(iters, local.stats.iterations() as u64);
    assert_eq!(evals, local.stats.total_dist_evals());

    // The connection is reusable: a second job (different derived seed)
    // solves on the same socket.
    let wspec1 = level1_spec(&base, 1);
    let p2 = rw.solve(1, &s.data, &wspec1, &mut |_| {}).unwrap();
    assert_eq!(p2.counts.iter().sum::<usize>(), 1200);
    let (tx, rx) = rw.traffic();
    assert!(tx > 0 && rx > 0);

    // Tear the worker down through the protocol.
    rw.request_shutdown().unwrap();
    w.wait().unwrap();
}

#[test]
fn dead_endpoint_falls_back_to_local_with_identical_results() {
    let s = generate_params(2400, 3, 4, 0.2, 1.0, 7);
    let spec = KmeansSpec::two_level(4).seed(3);
    let local = Coordinator::new(Backend::Cpu).run(&s.data, &spec);
    // Port 1 refuses: the endpoint is counted as a fallback and the run
    // proceeds all-local, bit-for-bit.
    let out = Coordinator::new(Backend::Cpu)
        .with_remotes(RemoteShardPool::new(vec!["127.0.0.1:1".into()]))
        .run(&s.data, &spec);
    assert_eq!(out.metrics.remote_workers, 0);
    assert_eq!(out.metrics.remote_shards, 0);
    assert_eq!(out.metrics.remote_fallbacks, 1);
    assert_bitwise_equal(&out.result, &local.result);
}

#[test]
fn mid_solve_wire_death_falls_back_to_local() {
    // The wire dies mid-solve on *every* connection: a real worker sits
    // behind a chaos proxy whose schedule kills the stream right after
    // the handshake + health checks (server frames 0–2: HelloAck, two
    // Pongs), i.e. on the first Iter frame — the nastiest failure point
    // (shard claimed, no result).  With no alternate endpoint the full
    // ladder runs: retry with reconnect, exhaust attempts, go local.
    let w = WorkerServer::spawn("127.0.0.1:0").unwrap();
    let proxy = ChaosProxy::spawn(
        "127.0.0.1:0",
        &w.addr().to_string(),
        FaultSchedule::parse("kill@3").unwrap(),
    )
    .unwrap();

    let s = generate_params(2000, 2, 3, 0.2, 1.0, 5);
    // P = 1 with one remote endpoint: zero local pullers spawn, so the
    // doomed remote executor *must* claim the shard — the fallback path
    // is exercised deterministically, never raced away.
    let spec = KmeansSpec::two_level(3).seed(2).shards(1);
    let local = Coordinator::new(Backend::Cpu).run(&s.data, &spec);
    let out = Coordinator::new(Backend::Cpu)
        .with_remotes(RemoteShardPool::new(vec![proxy.addr().to_string()]))
        .run(&s.data, &spec);

    assert_eq!(out.metrics.remote_workers, 1, "the handshake succeeded");
    assert_eq!(out.metrics.remote_shards, 0, "no shard completed remotely");
    assert_eq!(out.metrics.remote_fallbacks, 1);
    // Default policy: 3 attempts → 2 retries, each on a fresh dial.
    assert_eq!(out.metrics.remote_retries, 2);
    assert_eq!(out.metrics.remote_reconnects, 2);
    assert_eq!(out.metrics.remote_rescheduled, 0, "nowhere to reschedule");
    assert_bitwise_equal(&out.result, &local.result);

    proxy.shutdown();
    w.shutdown().unwrap();
}

#[test]
fn version_skew_is_refused_and_survived() {
    let w = WorkerServer::spawn("127.0.0.1:0").unwrap();
    let mut conn = TcpStream::connect(w.addr()).unwrap();
    Message::Hello {
        version: PROTOCOL_VERSION + 1,
    }
    .write_to(&mut conn)
    .unwrap();
    let (reply, _) = Message::read_from(&mut conn).unwrap();
    match reply {
        Message::Error { code, message } => {
            assert_eq!(code, ERR_VERSION_SKEW);
            assert!(message.contains("protocol"), "{message}");
        }
        other => panic!("expected a version-skew error, got {other:?}"),
    }
    drop(conn);
    // The worker survives the skewed peer: a well-versioned client still
    // handshakes.
    let ok = RemoteWorker::connect(&w.addr().to_string()).unwrap();
    drop(ok);
    w.shutdown().unwrap();
}

#[test]
fn hostile_bytes_do_not_kill_the_worker() {
    let w = WorkerServer::spawn("127.0.0.1:0").unwrap();
    // A peer speaking the wrong protocol entirely.
    let mut conn = TcpStream::connect(w.addr()).unwrap();
    conn.write_all(b"GET / HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    drop(conn);
    // A peer that connects and says nothing.
    drop(TcpStream::connect(w.addr()).unwrap());
    // The accept loop is still alive and serving.
    let ok = RemoteWorker::connect(&w.addr().to_string()).unwrap();
    drop(ok);
    w.shutdown().unwrap();
}

// ---------------------------------------------------------------------------
// Binary-level lifecycle and CLI validation
// ---------------------------------------------------------------------------

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_muchswift"))
}

#[test]
fn shard_worker_binary_starts_serves_and_shuts_down() {
    let mut child = bin()
        .args(["shard-worker", "--listen", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .spawn()
        .unwrap();
    // Scrape the bound address from the first stdout line.
    let mut reader = BufReader::new(child.stdout.take().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let addr = line
        .split("listening on ")
        .nth(1)
        .unwrap_or_else(|| panic!("unexpected banner: {line:?}"))
        .split_whitespace()
        .next()
        .unwrap()
        .to_string();
    // It actually serves: a real handshake round-trips.
    let rw = RemoteWorker::connect(&addr).unwrap();
    drop(rw);
    // Protocol-level shutdown exits the process cleanly.
    remote::shutdown_worker(&addr).unwrap();
    let status = child.wait().unwrap();
    assert!(status.success(), "shard-worker exited with {status}");
}

#[test]
fn cluster_remote_flags_are_validated() {
    // --remote outside the coordinator path is refused.
    let out = bin()
        .args([
            "cluster", "--n", "200", "--d", "2", "--k", "2", "--algo", "lloyd",
            "--remote", "127.0.0.1:7601",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--remote"), "{err}");
    // So is --report.
    let out = bin()
        .args([
            "cluster", "--n", "200", "--d", "2", "--k", "2", "--algo", "two-level",
            "--trace", "--report", "r.json",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--report"), "{err}");
}

#[test]
fn cluster_binary_survives_a_dead_remote_and_reports_the_fallback() {
    let dir = std::env::temp_dir().join(format!(
        "muchswift_remote_cli_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let report = dir.join("BENCH_distributed_test.json");
    let out = bin()
        .args([
            "cluster", "--n", "2000", "--d", "3", "--k", "4", "--backend", "cpu",
            "--remote", "127.0.0.1:1",
            "--report", report.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = std::fs::read_to_string(&report).unwrap();
    assert!(text.contains("\"placeholder\":false"), "{text}");
    assert!(text.contains("\"remote_fallbacks\":1"), "{text}");
    assert!(text.contains("\"remote_shards\":0"), "{text}");
    // The complete CoordMetrics counter set, pinned: `pallas-lint`'s
    // metrics-parity rule proves every declared counter reaches the
    // report emitter; this proves the emitted keys spell the field names
    // exactly (a typo'd key passes a token scan but fails here).
    for key in [
        "total_s",
        "partition_s",
        "tree_build_s",
        "level1_s",
        "combine_s",
        "level2_s",
        "offload_batches",
        "offload_jobs",
        "pjrt_executions",
        "pjrt_exec_s",
        "observed_iters",
        "observed_dist_evals",
        "shards",
        "shard_iters",
        "shard_dist_evals",
        "remote_workers",
        "remote_shards",
        "remote_fallbacks",
        "remote_retries",
        "remote_timeouts",
        "remote_reconnects",
        "remote_rescheduled",
        "remote_failed_endpoints",
        "remote_bytes_tx",
        "remote_bytes_rx",
        "sessions",
        "centroid_bcasts",
        "partials_rx",
        "session_bytes_tx",
        "session_bytes_rx",
        "shard_reloads",
        "bound_pruned_points",
        "bound_pruned_candidates",
        "bounds_matrix_cost",
    ] {
        assert!(text.contains(&format!("\"{key}\"")), "report lacks {key}: {text}");
    }
    std::fs::remove_dir_all(&dir).ok();
}
