//! Cross-module integration + failure injection (no PJRT here;
//! `runtime_pjrt.rs` covers the artifact path).

use muchswift::arch::{evaluate, ArchKind};
use muchswift::config::{toml::Doc, PlatformConfig, WorkloadConfig};
use muchswift::coordinator::{Backend, Coordinator};
use muchswift::data::{csv, synthetic, Dataset};
use muchswift::hw::dma::DmaEngine;
use muchswift::hw::resources;
use muchswift::kmeans::init::Init;
use muchswift::kmeans::solver::KmeansSpec;
use muchswift::kmeans::Metric;
use muchswift::runtime::Manifest;
use std::path::Path;

/// Config file -> platform -> simulator -> evaluation, end to end.
#[test]
fn config_to_simulation_pipeline() {
    let doc = Doc::parse(
        r#"
        name = "slow-board"
        [pl]
        freq_hz = 100e6
        [io]
        pcie_bytes_per_s = 0.4e9
        "#,
    )
    .unwrap();
    let slow = PlatformConfig::from_doc(&doc);
    slow.validate().unwrap();
    assert_eq!(slow.name, "slow-board");

    // Slower board => slower ingest, in proportion.
    let fast = PlatformConfig::zcu102();
    let bytes = 8 << 20;
    let mut d_slow = DmaEngine::new(&slow);
    let mut d_fast = DmaEngine::new(&fast);
    let t_slow = d_slow.ingest(0, bytes).finish_ps as f64;
    let t_fast = d_fast.ingest(0, bytes).finish_ps as f64;
    let ratio = t_slow / t_fast;
    assert!((3.0..5.0).contains(&ratio), "pcie 4x slower -> ~4x ingest, got {ratio:.2}");
}

/// CSV round trip feeds the coordinator identically to in-memory data.
#[test]
fn csv_to_coordinator_round_trip() {
    let s = synthetic::generate_params(800, 3, 3, 0.1, 2.0, 99);
    let dir = std::env::temp_dir().join("muchswift_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("pipeline.csv");
    csv::save(&s.data, &path).unwrap();
    let loaded = csv::load(&path).unwrap();
    assert_eq!(loaded, s.data);

    let coord = Coordinator::new(Backend::Cpu);
    let spec = KmeansSpec::two_level(3).seed(5).init(Init::KmeansPlusPlus);
    let a = coord.run(&s.data, &spec);
    let b = coord.run(&loaded, &spec);
    assert_eq!(a.result.assignments, b.result.assignments);
    assert_eq!(a.result.centroids, b.result.centroids);
    std::fs::remove_file(&path).ok();
}

/// The paper's qualitative ordering holds on a mid-size workload:
/// software-only slowest, MUCH-SWIFT fastest, everything else between.
#[test]
fn architecture_ordering_is_stable() {
    let w = WorkloadConfig {
        n: 100_000,
        d: 15,
        k: 10,
        true_k: 10,
        sigma: 0.15,
        seed: 31,
        max_iters: 50,
        ..Default::default()
    };
    let t = |k: ArchKind| evaluate(k, &w).total_s;
    let ms = t(ArchKind::MuchSwift);
    let sw = t(ArchKind::SwLloyd);
    let conv = t(ArchKind::FpgaLloydSingle);
    let w13 = t(ArchKind::FpgaFilterSingle);
    let w17 = t(ArchKind::FpgaLloydMulti);
    let swf = t(ArchKind::SwFilter);
    assert!(ms < w13 && ms < w17 && ms < conv && ms < sw, "much-swift must win");
    assert!(swf < sw, "software filtering beats software lloyd");
    assert!(w13 < conv, "[13] beats the unoptimized FPGA");
    // Filtering on FPGA beats parallel-but-unfiltered hardware at this K.
    assert!(w13 < w17, "[13] {w13} vs [17] {w17}");
}

/// Deterministic: same workload/seed -> identical evaluation twice.
#[test]
fn evaluation_is_deterministic() {
    let w = WorkloadConfig {
        n: 50_000,
        d: 8,
        k: 6,
        true_k: 6,
        seed: 77,
        max_iters: 40,
        ..Default::default()
    };
    let a = evaluate(ArchKind::MuchSwift, &w);
    let b = evaluate(ArchKind::MuchSwift, &w);
    assert_eq!(a.total_s, b.total_s);
    assert_eq!(a.iterations, b.iterations);
}

/// Section 4.2 capacity claim: the paper's N=100000, K=1024 example fits
/// the 1 GB DDR3 with room to spare, and Table-1 feasibility limits K for
/// the fully-parallel PL build.
#[test]
fn ddr3_capacity_and_resource_limits() {
    let w = WorkloadConfig {
        n: 100_000,
        d: 15,
        k: 1024,
        true_k: 8,
        ..Default::default()
    };
    let cfg = PlatformConfig::zcu102();
    assert!(w.dataset_bytes() * 4 < cfg.ddr3_capacity, "dataset (+tree) must fit DDR3");
    assert!(!resources::fits(1024), "K=1024 cannot be fully parallel");
    assert!(resources::fits(20), "K=20 is the paper's feasible point");
}

// ---------------------------------------------------------------------------
// Failure injection
// ---------------------------------------------------------------------------

#[test]
fn manifest_failures_are_clean_errors() {
    let dir = Path::new("/tmp/muchswift_missing_artifacts");
    let err = Manifest::load(dir).unwrap_err();
    assert!(format!("{err}").contains("make artifacts"), "{err}");

    // Corrupted JSON.
    let bad = Manifest::parse("{not json", dir);
    assert!(bad.is_err());
    // Valid JSON, wrong schema.
    let bad = Manifest::parse(r#"{"format_version": 1, "pad_sentinel": 1e17, "entries": [{}]}"#, dir);
    assert!(bad.is_err());
    // Empty entry list.
    let bad = Manifest::parse(r#"{"format_version": 1, "pad_sentinel": 1e17, "entries": []}"#, dir);
    assert!(bad.is_err());
}

#[test]
fn degenerate_datasets_do_not_crash() {
    // All-identical points.
    let data = Dataset::from_flat(64, 3, vec![1.5; 192]);
    let coord = Coordinator::new(Backend::Cpu);
    let out = coord.run(
        &data,
        &KmeansSpec::two_level(4).seed(1),
    );
    assert_eq!(out.result.assignments.len(), 64);
    // One cluster gets everything; the rest stay empty.
    let sizes = out.result.sizes();
    assert_eq!(sizes.iter().sum::<usize>(), 64);
    assert_eq!(sizes.iter().filter(|&&s| s > 0).count(), 1);

    // Single point, k=1.
    let single = Dataset::from_flat(1, 2, vec![3.0, 4.0]);
    let out = coord.run(&single, &KmeansSpec::two_level(1));
    assert_eq!(out.result.centroids.point(0), &[3.0, 4.0]);

    // Manhattan end to end.
    let s = synthetic::generate_params(500, 2, 3, 0.2, 1.0, 8);
    let out = coord.run(
        &s.data,
        &KmeansSpec::two_level(3).metric(Metric::Manhattan),
    );
    assert!(out.result.stats.converged);
}

#[test]
#[should_panic(expected = "k out of range")]
fn k_larger_than_n_is_rejected() {
    let data = Dataset::from_flat(3, 1, vec![1.0, 2.0, 3.0]);
    let coord = Coordinator::new(Backend::Cpu);
    coord.run(&data, &KmeansSpec::two_level(10));
}

#[test]
fn workload_validation_rejects_nonsense() {
    for bad in [
        "[workload]\nn = 0",
        "[workload]\nd = 0",
        "[workload]\nn = 5\nk = 9",
        "[workload]\nmax_iters = 0",
    ] {
        let doc = Doc::parse(bad).unwrap();
        assert!(WorkloadConfig::from_doc(&doc).is_err(), "should reject: {bad}");
    }
}

/// The shipped config files parse and match the built-in profiles.
#[test]
fn shipped_configs_parse_and_match_profiles() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let zcu = PlatformConfig::from_toml_file(&root.join("configs/zcu102.toml")).unwrap();
    assert_eq!(zcu, PlatformConfig::zcu102());
    let w13 = PlatformConfig::from_toml_file(&root.join("configs/fpl13_winterstein.toml")).unwrap();
    assert_eq!(w13, PlatformConfig::winterstein_fpl13());
    let c16 = PlatformConfig::from_toml_file(&root.join("configs/fpl16_canilho.toml")).unwrap();
    assert_eq!(c16, PlatformConfig::canilho_fpl16());
    let wl = WorkloadConfig::from_toml_file(&root.join("configs/workload_fig3.toml")).unwrap();
    assert_eq!(wl.n, 1_000_000);
    assert_eq!(wl.d, 15);
    assert_eq!(wl.k, 20);
}
