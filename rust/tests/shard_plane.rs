//! Shard-plane parity suite: the P-way generalization must reproduce the
//! legacy fixed 4-quarter two-level architecture exactly at P = 4, and
//! degenerate sensibly at the edges (P = 1, P ≫ cores).
//!
//! The reference implementations in this file are *verbatim copies of the
//! pre-refactor code* (modulo-4 dealing, the depth-2 kd subtree
//! quartering, the flat greedy combine), so the parity assertions are
//! against the historical behavior, not against the new code itself.

use muchswift::coordinator::{Backend, Coordinator};
use muchswift::data::synthetic::generate_params;
use muchswift::data::Dataset;
use muchswift::kdtree::KdTree;
use muchswift::kmeans::shard::{
    combine_hierarchical, combine_level, plan_kd_frontier, plan_round_robin, Partition,
    ShardPlan,
};
use muchswift::kmeans::solver::{Algo, KmeansSpec, SolverCtx};
use muchswift::kmeans::twolevel::{self, TwoLevelOpts, QUARTERS};
use muchswift::kmeans::Metric;

// ---------------------------------------------------------------------------
// Legacy reference implementations (pre-refactor code, kept verbatim)
// ---------------------------------------------------------------------------

/// Pre-refactor `quarter_round_robin`: deal rows out modulo 4.
fn legacy_quarter_round_robin(data: &Dataset) -> (Vec<Dataset>, Vec<Vec<u32>>) {
    let mut ids: Vec<Vec<u32>> = vec![Vec::with_capacity(data.len() / 4 + 1); 4];
    for i in 0..data.len() {
        ids[i % 4].push(i as u32);
    }
    let datasets = ids
        .iter()
        .map(|rows| {
            let rows_usize: Vec<usize> = rows.iter().map(|&r| r as usize).collect();
            data.gather(&rows_usize)
        })
        .collect();
    (datasets, ids)
}

/// Pre-refactor `quarter`: the 4 subtrees two levels below the root, with
/// the contiguous fallback for shallow trees.
fn legacy_quarter(data: &Dataset, tree: &KdTree) -> (Vec<Dataset>, Vec<Vec<u32>>) {
    let mut fronts: Vec<u32> = vec![0];
    for _ in 0..2 {
        let mut next = Vec::with_capacity(fronts.len() * 2);
        for &ni in &fronts {
            let n = &tree.nodes[ni as usize];
            if n.is_leaf() {
                next.push(ni);
            } else {
                next.push(n.left);
                next.push(n.right);
            }
        }
        fronts = next;
    }
    if fronts.len() < 4 {
        let (parts, offsets) = data.split_contiguous(4);
        let ids = offsets
            .iter()
            .zip(parts.iter())
            .map(|(&o, p)| (o as u32..(o + p.len()) as u32).collect())
            .collect();
        return (parts, ids);
    }
    let mut datasets = Vec::with_capacity(4);
    let mut ids = Vec::with_capacity(4);
    for &ni in fronts.iter().take(4) {
        let node = &tree.nodes[ni as usize];
        let rows: Vec<u32> = tree.node_points(node).to_vec();
        let rows_usize: Vec<usize> = rows.iter().map(|&r| r as usize).collect();
        datasets.push(data.gather(&rows_usize));
        ids.push(rows);
    }
    (datasets, ids)
}

/// Pre-refactor `combine`: one flat greedy count-weighted pass.
fn legacy_combine(centroids: &[Dataset], counts: &[Vec<usize>], metric: Metric) -> Dataset {
    let q = centroids.len();
    assert!(q >= 1);
    let k = centroids[0].len();
    let d = centroids[0].dims();
    let mut out = Vec::with_capacity(k * d);
    let mut used: Vec<Vec<bool>> = centroids.iter().map(|c| vec![false; c.len()]).collect();
    for a in 0..k {
        let anchor = centroids[0].point(a);
        let mut wsum: Vec<f64> = anchor
            .iter()
            .map(|&v| v as f64 * counts[0][a] as f64)
            .collect();
        let mut wtot = counts[0][a] as f64;
        for qi in 1..q {
            let mut best: Option<(usize, f32)> = None;
            for c in 0..centroids[qi].len() {
                if used[qi][c] {
                    continue;
                }
                let dd = metric.dist(anchor, centroids[qi].point(c));
                if best.map_or(true, |(_, bd)| dd < bd) {
                    best = Some((c, dd));
                }
            }
            if let Some((c, _)) = best {
                used[qi][c] = true;
                let w = counts[qi][c] as f64;
                for (j, &v) in centroids[qi].point(c).iter().enumerate() {
                    wsum[j] += v as f64 * w;
                }
                wtot += w;
            }
        }
        if wtot <= 0.0 {
            out.extend_from_slice(anchor);
        } else {
            out.extend(wsum.iter().map(|&v| (v / wtot) as f32));
        }
    }
    Dataset::from_flat(k, d, out)
}

/// Deterministic pseudo-random centroid sets + counts for combine tests.
fn fake_level1(p: usize, k: usize, d: usize, salt: u64) -> (Vec<Dataset>, Vec<Vec<usize>>) {
    let mut sets = Vec::with_capacity(p);
    let mut counts = Vec::with_capacity(p);
    for s in 0..p {
        let mut flat = Vec::with_capacity(k * d);
        for i in 0..k * d {
            let x = (s as u64)
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add((i as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9))
                .wrapping_add(salt);
            flat.push(((x >> 33) % 1000) as f32 * 0.017 - 8.5);
        }
        sets.push(Dataset::from_flat(k, d, flat));
        counts.push((0..k).map(|i| (s * 31 + i * 7) % 90 + 1).collect());
    }
    (sets, counts)
}

// ---------------------------------------------------------------------------
// Plan parity at P = 4
// ---------------------------------------------------------------------------

#[test]
fn round_robin_plan_matches_legacy_quartering_bitwise() {
    for n in [1usize, 3, 4, 997, 2000] {
        let s = generate_params(n, 3, 2.min(n), 0.3, 1.0, 7);
        let (lp, li) = legacy_quarter_round_robin(&s.data);
        let (np, ni) = plan_round_robin(&s.data, QUARTERS);
        assert_eq!(li, ni, "n={n}");
        assert_eq!(lp, np, "n={n}");
        // And through the ShardPlan front door.
        let plan = ShardPlan::build(&s.data, 4, Partition::RoundRobin, None);
        assert_eq!(plan.ids, li);
        assert_eq!(plan.parts, lp);
    }
}

#[test]
fn kd_frontier_plan_matches_legacy_quartering_bitwise() {
    // Deep trees (the grandchild path) and shallow trees (the contiguous
    // fallback path) both reproduce the legacy split exactly.
    for (n, seed) in [(2000usize, 11u64), (5000, 23), (3, 1), (9, 5)] {
        let s = generate_params(n, 3, 2.min(n), 0.25, 1.0, seed);
        let tree = KdTree::build(&s.data);
        let (lp, li) = legacy_quarter(&s.data, &tree);
        let (np, ni) = plan_kd_frontier(&s.data, &tree, QUARTERS);
        assert_eq!(li, ni, "n={n}");
        assert_eq!(lp, np, "n={n}");
        let plan = ShardPlan::build(&s.data, 4, Partition::KdTop, Some(&tree));
        assert_eq!(plan.ids, li);
    }
}

// ---------------------------------------------------------------------------
// Combine parity
// ---------------------------------------------------------------------------

/// The pre-heap frontier fold, verbatim (PR 4's code): expand the kd
/// frontier to >= P nodes, then repeatedly merge the adjacent pair with
/// the smallest combined size, found by a full linear re-scan each time
/// (leftmost wins ties).
fn legacy_plan_kd_frontier(
    data: &Dataset,
    tree: &KdTree,
    shards: usize,
) -> (Vec<Dataset>, Vec<Vec<u32>>) {
    assert!(shards >= 1);
    let rounds = shards.next_power_of_two().trailing_zeros();
    let mut fronts: Vec<u32> = vec![0];
    for _ in 0..rounds {
        let mut next = Vec::with_capacity(fronts.len() * 2);
        for &ni in &fronts {
            let n = &tree.nodes[ni as usize];
            if n.is_leaf() {
                next.push(ni);
            } else {
                next.push(n.left);
                next.push(n.right);
            }
        }
        fronts = next;
    }
    if fronts.len() < shards {
        let (parts, offsets) = data.split_contiguous(shards);
        let ids = offsets
            .iter()
            .zip(parts.iter())
            .map(|(&o, p)| (o as u32..(o + p.len()) as u32).collect())
            .collect();
        return (parts, ids);
    }
    let mut ids: Vec<Vec<u32>> = fronts
        .iter()
        .map(|&ni| tree.node_points(&tree.nodes[ni as usize]).to_vec())
        .collect();
    while ids.len() > shards {
        let mut best = 0usize;
        let mut best_len = usize::MAX;
        for i in 0..ids.len() - 1 {
            let len = ids[i].len() + ids[i + 1].len();
            if len < best_len {
                best_len = len;
                best = i;
            }
        }
        let right = ids.remove(best + 1);
        ids[best].extend_from_slice(&right);
    }
    let datasets = ids
        .iter()
        .map(|rows| {
            let rows_usize: Vec<usize> = rows.iter().map(|&r| r as usize).collect();
            data.gather(&rows_usize)
        })
        .collect();
    (datasets, ids)
}

#[test]
fn heap_driven_frontier_fold_pins_the_legacy_plans() {
    // The heap rewrite of the frontier folding must reproduce the
    // pre-heap plans exactly — same shard membership, same order — at
    // the issue's pinned P ∈ {2, 4, 8} (no folding: fronts == P) and,
    // crucially, at every non-power-of-two P where folding actually
    // runs, on several datasets including skewed ones that bottom out
    // early and force uneven frontier node sizes.
    for (n, d, k, seed) in [
        (2000usize, 3usize, 4usize, 11u64),
        (1003, 2, 6, 5),
        (517, 5, 2, 93),
        (64, 2, 1, 7),
    ] {
        let s = generate_params(n, d, k, 0.3, 1.0, seed);
        let tree = KdTree::build(&s.data);
        for p in [2usize, 4, 8, 3, 5, 6, 7, 9, 11, 13, 16, 25] {
            let (want_parts, want_ids) = legacy_plan_kd_frontier(&s.data, &tree, p);
            let plan = ShardPlan::build(&s.data, p, Partition::KdTop, Some(&tree));
            assert_eq!(plan.ids, want_ids, "n={n} P={p}: row ids diverged");
            assert_eq!(plan.parts, want_parts, "n={n} P={p}: gathered shards diverged");
            // And through the free function the plan builder wraps.
            let (fparts, fids) = plan_kd_frontier(&s.data, &tree, p);
            assert_eq!(fids, want_ids);
            assert_eq!(fparts, want_parts);
        }
    }
}

#[test]
fn hierarchical_combine_equals_flat_greedy_combine_up_to_p4() {
    for metric in [Metric::Euclid, Metric::Manhattan] {
        for p in 1..=4usize {
            let (sets, counts) = fake_level1(p, 6, 3, 99);
            let legacy = legacy_combine(&sets, &counts, metric);
            let flat = combine_level(&sets, &counts, metric).0;
            let tree = combine_hierarchical(&sets, &counts, metric);
            assert_eq!(legacy, flat, "{metric:?} P={p}: combine_level drifted");
            assert_eq!(legacy, tree, "{metric:?} P={p}: hierarchical drifted");
        }
    }
}

#[test]
fn hierarchical_combine_scales_past_the_greedy_pass() {
    // Above the fan-in the tree reduce takes over; output stays a valid
    // k x d set and matches a hand-built two-level reduction.
    let (sets, counts) = fake_level1(16, 5, 4, 3);
    let got = combine_hierarchical(&sets, &counts, Metric::Euclid);
    assert_eq!(got.len(), 5);
    assert_eq!(got.dims(), 4);
    let mut mids = Vec::new();
    let mut midc = Vec::new();
    for g in 0..4 {
        let (m, c) = combine_level(&sets[g * 4..g * 4 + 4], &counts[g * 4..g * 4 + 4], Metric::Euclid);
        mids.push(m);
        midc.push(c);
    }
    assert_eq!(got, combine_level(&mids, &midc, Metric::Euclid).0);
}

// ---------------------------------------------------------------------------
// Solver / coordinator parity
// ---------------------------------------------------------------------------

#[test]
fn p4_spec_reproduces_the_legacy_two_level_run_on_both_partitions() {
    let s = generate_params(3000, 3, 5, 0.15, 2.0, 33);
    for partition in [Partition::RoundRobin, Partition::KdTop] {
        let spec = KmeansSpec::two_level(5).seed(9).shards(4).partition(partition);
        let a = spec.solve(&mut SolverCtx::new(&s.data));
        let b = twolevel::run(
            &s.data,
            5,
            &TwoLevelOpts {
                seed: 9,
                partition,
                ..Default::default()
            },
        );
        assert_eq!(a.centroids, b.centroids, "{partition:?}");
        assert_eq!(a.assignments, b.assignments, "{partition:?}");
        let ea = a.ext.two_level.as_ref().unwrap();
        let eb = b.ext.two_level.as_ref().unwrap();
        assert_eq!(ea.quarter_sizes, eb.quarter_sizes);
        assert_eq!(ea.merged_centroids, eb.merged_centroids);
        // An explicit shards(4) is exactly the default.
        let c = KmeansSpec::two_level(5).seed(9).partition(partition)
            .solve(&mut SolverCtx::new(&s.data));
        assert_eq!(a.centroids, c.centroids);
        assert_eq!(a.assignments, c.assignments);
    }
}

#[test]
fn coordinator_p4_matches_the_sequential_reference_outcome() {
    // The acceptance pin, in two sound halves:
    // (a) an explicit `shards(4)` is bitwise the default coordinator run —
    //     the P = 4 special case is the unchanged code path;
    // (b) against the sequential reference the coordinator holds exactly
    //     the invariants the pre-refactor test pinned (equal per-quarter
    //     trajectories, near-identical centroids, same objective) — the
    //     batched-vs-recursive engines may still differ on distance ties,
    //     which predates the shard plane.
    let s = generate_params(3000, 3, 5, 0.15, 2.0, 33);
    let coord = Coordinator::new(Backend::Cpu);
    let c4 = coord.run(&s.data, &KmeansSpec::two_level(5).seed(9).shards(4));
    let cd = coord.run(&s.data, &KmeansSpec::two_level(5).seed(9));
    assert_eq!(c4.result.centroids, cd.result.centroids);
    assert_eq!(c4.result.assignments, cd.result.assignments);

    let r = twolevel::run(&s.data, 5, &TwoLevelOpts { seed: 9, ..Default::default() });
    let ce = c4.result.ext.two_level.as_ref().unwrap();
    let re = r.ext.two_level.as_ref().unwrap();
    assert_eq!(ce.quarter_sizes, vec![750; 4]);
    assert_eq!(ce.quarter_sizes, re.quarter_sizes);
    for (a, b) in ce.level1_stats.iter().zip(re.level1_stats.iter()) {
        assert_eq!(a.iterations(), b.iterations());
    }
    for (a, b) in c4.result.centroids.iter().zip(r.centroids.iter()) {
        for (x, y) in a.iter().zip(b.iter()) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }
    let obj_c = c4.result.objective(&s.data, Metric::Euclid);
    let obj_r = r.objective(&s.data, Metric::Euclid);
    assert!(
        (obj_c - obj_r).abs() <= 1e-4 * (1.0 + obj_r.abs()),
        "{obj_c} vs {obj_r}"
    );
}

#[test]
fn p1_degenerates_to_a_plain_filtering_run() {
    let s = generate_params(2000, 3, 4, 0.2, 2.0, 13);
    let spec = KmeansSpec::two_level(4).seed(6).shards(1);
    let two = spec.solve(&mut SolverCtx::new(&s.data));
    let ext = two.ext.two_level.as_ref().unwrap();
    assert_eq!(ext.quarter_sizes, vec![2000]);
    assert_eq!(ext.level1_stats.len(), 1);
    let plain = KmeansSpec::new(4)
        .algo(Algo::Filter)
        .seed(6)
        .solve(&mut SolverCtx::new(&s.data));
    let obj_two = two.objective(&s.data, Metric::Euclid);
    let obj_plain = plain.objective(&s.data, Metric::Euclid);
    assert!(
        (obj_two - obj_plain).abs() <= 1e-3 * (1.0 + obj_plain.abs()),
        "P=1 two-level {obj_two} vs plain filtering {obj_plain}"
    );
}

#[test]
fn p8_runs_and_partitions_correctly_everywhere() {
    let s = generate_params(4000, 3, 5, 0.15, 2.0, 29);
    for partition in [Partition::RoundRobin, Partition::KdTop, Partition::Contiguous] {
        let spec = KmeansSpec::two_level(5).seed(4).shards(8).partition(partition);
        let seq = spec.solve(&mut SolverCtx::new(&s.data));
        let ext = seq.ext.two_level.as_ref().unwrap();
        assert_eq!(ext.level1_stats.len(), 8, "{partition:?}");
        assert_eq!(ext.quarter_sizes.iter().sum::<usize>(), 4000);
        // The threaded system agrees with the sequential reference on the
        // per-shard trajectories.
        let coord = Coordinator::new(Backend::Cpu).run(&s.data, &spec);
        let cext = coord.result.ext.two_level.as_ref().unwrap();
        assert_eq!(cext.quarter_sizes, ext.quarter_sizes);
        assert_eq!(
            cext.level1_stats.iter().map(|st| st.iterations()).collect::<Vec<_>>(),
            ext.level1_stats.iter().map(|st| st.iterations()).collect::<Vec<_>>(),
        );
        assert_eq!(coord.metrics.shards, 8);
    }
}
