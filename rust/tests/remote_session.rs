//! Session plane integration suite (protocol v3).
//!
//! The headline pin demanded by the plane's whole design: a loopback
//! **session-mode** run — shards shipped once, every iteration crossing
//! the wire as an O(k·d) `Centroids`/`Partials` exchange — is
//! byte-identical (labels, centroids, merged level-2 seed) to the
//! in-process solve.  Around it: the resident-memory budget's refusal
//! path, the raw v3 conversation a hostile/naive peer sees, and the
//! `cluster --session` CLI contract.

use muchswift::coordinator::{Backend, Coordinator};
use muchswift::data::synthetic::generate_params;
use muchswift::data::Dataset;
use muchswift::kmeans::remote::protocol::{
    dataset_checksum, CentroidsFrame, LoadShardFrame, Message, ERR_BAD_CHECKSUM, ERR_NO_SHARD,
    ERR_RESIDENT_LIMIT, PROTOCOL_VERSION,
};
use muchswift::kmeans::remote::{RemoteShardPool, WorkerServer};
use muchswift::kmeans::solver::KmeansSpec;
use muchswift::kmeans::{KmeansResult, Metric};
use std::net::TcpStream;
use std::process::Command;

fn assert_bitwise_equal(a: &KmeansResult, b: &KmeansResult) {
    assert_eq!(a.centroids.len(), b.centroids.len());
    for (x, y) in a.centroids.flat().iter().zip(b.centroids.flat()) {
        assert_eq!(x.to_bits(), y.to_bits(), "centroid bits diverged");
    }
    assert_eq!(a.assignments, b.assignments, "assignments diverged");
}

#[test]
fn loopback_session_run_is_bitwise_identical_to_in_process() {
    let s = generate_params(6000, 3, 5, 0.15, 2.0, 33);
    let spec = KmeansSpec::two_level(5).seed(9).shards(4).workers(4);

    // In-process baseline.
    let local = Coordinator::new(Backend::Cpu).run(&s.data, &spec);

    // Two loopback workers, two session connections each: four homes for
    // four shards, so every level-1 iteration provably crossed the wire.
    let w1 = WorkerServer::spawn("127.0.0.1:0").unwrap();
    let w2 = WorkerServer::spawn("127.0.0.1:0").unwrap();
    let (a1, a2) = (w1.addr().to_string(), w2.addr().to_string());
    let pool = RemoteShardPool::new(vec![a1.clone(), a2.clone(), a1, a2]);
    let out = Coordinator::new(Backend::Cpu)
        .with_session(true)
        .with_remotes(pool)
        .run(&s.data, &spec);

    assert_bitwise_equal(&out.result, &local.result);
    // The two-level extension travels intact: per-shard stats and the
    // merged level-2 seed carry the same bits.
    let le = local.result.ext.two_level.as_ref().unwrap();
    let re = out.result.ext.two_level.as_ref().unwrap();
    assert_eq!(re.quarter_sizes, le.quarter_sizes);
    assert_eq!(
        re.level1_stats.iter().map(|st| st.iterations()).collect::<Vec<_>>(),
        le.level1_stats.iter().map(|st| st.iterations()).collect::<Vec<_>>(),
    );
    for (x, y) in re
        .merged_centroids
        .flat()
        .iter()
        .zip(le.merged_centroids.flat())
    {
        assert_eq!(x.to_bits(), y.to_bits(), "merged seed bits diverged");
    }

    // Session accounting.  All four shards stayed resident remotely, no
    // recovery rung ever fired …
    assert_eq!(out.metrics.remote_workers, 4);
    assert_eq!(out.metrics.sessions, 4, "each connection hosted a shard");
    assert_eq!(out.metrics.remote_shards, 4);
    assert_eq!(out.metrics.remote_fallbacks, 0);
    assert_eq!(out.metrics.shard_reloads, 0);
    // … every folded iteration cost exactly one broadcast and one reduce …
    let total_iters: u64 = local.metrics.shard_iters.iter().sum();
    assert_eq!(out.metrics.centroid_bcasts, total_iters);
    assert_eq!(out.metrics.partials_rx, total_iters);
    // … and the steady-state traffic is real but dwarfed by the one-time
    // shard uploads (remote_bytes includes the LoadShard frames).
    assert!(out.metrics.session_bytes_tx > 0);
    assert!(out.metrics.session_bytes_rx > 0);
    assert!(
        out.metrics.session_bytes_tx < out.metrics.remote_bytes_tx,
        "per-iteration bytes ({}) should be a fraction of total tx ({})",
        out.metrics.session_bytes_tx,
        out.metrics.remote_bytes_tx
    );
    // The folded iterations streamed the same live counters the local
    // observers would have.
    assert_eq!(out.metrics.shard_iters, local.metrics.shard_iters);
    assert_eq!(out.metrics.shard_dist_evals, local.metrics.shard_dist_evals);
    assert_eq!(out.metrics.observed_iters, local.metrics.observed_iters);
    // All-local runs report a zeroed session section.
    assert_eq!(local.metrics.sessions, 0);
    assert_eq!(local.metrics.centroid_bcasts, 0);

    w1.shutdown().unwrap();
    w2.shutdown().unwrap();
}

#[test]
fn resident_budget_refusal_falls_back_local_with_identical_results() {
    let s = generate_params(2400, 3, 4, 0.2, 1.0, 7);
    let spec = KmeansSpec::two_level(4).seed(3).shards(2);
    let local = Coordinator::new(Backend::Cpu).run(&s.data, &spec);

    // A worker whose resident budget can't hold even one shard refuses
    // every LoadShard (ERR_RESIDENT_LIMIT); the driver falls back to
    // local stepping and the results are unaffected.
    let w = WorkerServer::spawn_with_resident_limit("127.0.0.1:0", 64).unwrap();
    let out = Coordinator::new(Backend::Cpu)
        .with_session(true)
        .with_remotes(RemoteShardPool::new(vec![w.addr().to_string()]))
        .run(&s.data, &spec);

    assert_bitwise_equal(&out.result, &local.result);
    assert_eq!(out.metrics.remote_workers, 1, "the handshake succeeded");
    assert_eq!(out.metrics.sessions, 0, "nothing went resident");
    assert_eq!(out.metrics.remote_shards, 0);
    assert_eq!(out.metrics.remote_fallbacks, 2, "both shards fell back");
    assert_eq!(out.metrics.centroid_bcasts, 0);
    assert_eq!(out.metrics.partials_rx, 0);

    w.shutdown().unwrap();
}

/// Drive the raw v3 conversation over a bare socket: the error space a
/// session peer can hit (step without residency, corrupt upload, budget
/// refusal), the idempotent Release, and EndSession leaving the
/// connection serviceable.
#[test]
fn raw_session_protocol_semantics() {
    let w = WorkerServer::spawn_with_resident_limit("127.0.0.1:0", 1 << 20).unwrap();
    let mut conn = TcpStream::connect(w.addr()).unwrap();
    Message::Hello {
        version: PROTOCOL_VERSION,
    }
    .write_to(&mut conn)
    .unwrap();
    match Message::read_from(&mut conn).unwrap().0 {
        Message::HelloAck { version } => assert_eq!(version, PROTOCOL_VERSION),
        other => panic!("expected HelloAck, got {other:?}"),
    }

    let data = Dataset::from_flat(6, 2, vec![
        0.0, 0.0, 0.1, 0.1, 0.2, 0.0, 5.0, 5.0, 5.1, 5.1, 5.0, 5.2,
    ]);
    let checksum = dataset_checksum(&data);

    // Stepping a shard that was never loaded is a clean protocol error.
    Message::Centroids(Box::new(CentroidsFrame {
        shard: 0,
        iter: 0,
        centroids: Dataset::from_flat(2, 2, vec![0.0, 0.0, 5.0, 5.0]),
    }))
    .write_to(&mut conn)
    .unwrap();
    match Message::read_from(&mut conn).unwrap().0 {
        Message::Error { code, .. } => assert_eq!(code, ERR_NO_SHARD),
        other => panic!("expected ERR_NO_SHARD, got {other:?}"),
    }

    // A corrupt upload (checksum mismatch) is refused without residency.
    Message::LoadShard(Box::new(LoadShardFrame {
        shard: 0,
        metric: Metric::Euclid,
        checksum: checksum ^ 0xDEAD_BEEF,
        data: data.clone(),
    }))
    .write_to(&mut conn)
    .unwrap();
    match Message::read_from(&mut conn).unwrap().0 {
        Message::Error { code, .. } => assert_eq!(code, ERR_BAD_CHECKSUM),
        other => panic!("expected ERR_BAD_CHECKSUM, got {other:?}"),
    }

    // The honest upload is acked with the checksum echoed.
    Message::LoadShard(Box::new(LoadShardFrame {
        shard: 0,
        metric: Metric::Euclid,
        checksum,
        data: data.clone(),
    }))
    .write_to(&mut conn)
    .unwrap();
    match Message::read_from(&mut conn).unwrap().0 {
        Message::LoadAck { shard, checksum: ack } => {
            assert_eq!(shard, 0);
            assert_eq!(ack, checksum);
        }
        other => panic!("expected LoadAck, got {other:?}"),
    }

    // A second shard that would blow the 1 MiB budget is refused while
    // shard 0 stays resident.
    let big_n = 40_000; // 40k × 2 dims × 4 B × 3 copies ≫ 1 MiB
    let big = Dataset::from_flat(big_n, 2, vec![0.5; big_n * 2]);
    Message::LoadShard(Box::new(LoadShardFrame {
        shard: 1,
        metric: Metric::Euclid,
        checksum: dataset_checksum(&big),
        data: big,
    }))
    .write_to(&mut conn)
    .unwrap();
    match Message::read_from(&mut conn).unwrap().0 {
        Message::Error { code, .. } => assert_eq!(code, ERR_RESIDENT_LIMIT),
        other => panic!("expected ERR_RESIDENT_LIMIT, got {other:?}"),
    }

    // Stepping the resident shard yields shaped partials: k sums rows,
    // k counts summing to n.
    Message::Centroids(Box::new(CentroidsFrame {
        shard: 0,
        iter: 0,
        centroids: Dataset::from_flat(2, 2, vec![0.0, 0.0, 5.0, 5.0]),
    }))
    .write_to(&mut conn)
    .unwrap();
    match Message::read_from(&mut conn).unwrap().0 {
        Message::Partials(p) => {
            assert_eq!(p.shard, 0);
            assert_eq!(p.iter, 0);
            assert_eq!(p.sums.len(), 2);
            assert_eq!(p.sums.dims(), 2);
            assert_eq!(p.counts.len(), 2);
            assert_eq!(p.counts.iter().sum::<u32>(), 6);
        }
        other => panic!("expected Partials, got {other:?}"),
    }

    // Release is acked — and idempotent, so a retried Release after a
    // reconnect can never error.
    for _ in 0..2 {
        Message::Release { shard: 0 }.write_to(&mut conn).unwrap();
        match Message::read_from(&mut conn).unwrap().0 {
            Message::Released { shard } => assert_eq!(shard, 0),
            other => panic!("expected Released, got {other:?}"),
        }
    }

    // EndSession has no reply and keeps the connection serving: a Ping
    // still answers, and the released shard is gone.
    Message::EndSession.write_to(&mut conn).unwrap();
    Message::Ping.write_to(&mut conn).unwrap();
    match Message::read_from(&mut conn).unwrap().0 {
        Message::Pong => {}
        other => panic!("expected Pong, got {other:?}"),
    }
    Message::Centroids(Box::new(CentroidsFrame {
        shard: 0,
        iter: 1,
        centroids: Dataset::from_flat(2, 2, vec![0.0, 0.0, 5.0, 5.0]),
    }))
    .write_to(&mut conn)
    .unwrap();
    match Message::read_from(&mut conn).unwrap().0 {
        Message::Error { code, .. } => assert_eq!(code, ERR_NO_SHARD),
        other => panic!("expected ERR_NO_SHARD after EndSession, got {other:?}"),
    }

    drop(conn);
    w.shutdown().unwrap();
}

// ---------------------------------------------------------------------------
// CLI contract
// ---------------------------------------------------------------------------

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_muchswift"))
}

#[test]
fn cluster_session_flag_is_validated_and_runs_all_local() {
    // --session outside the two-level coordinator path is refused.
    let out = bin()
        .args([
            "cluster", "--n", "200", "--d", "2", "--k", "2", "--algo", "lloyd",
            "--session",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--session"), "{err}");

    // On the coordinator path it works with no remotes at all (pure
    // local lockstep) and reports a zeroed session section.
    let dir = std::env::temp_dir().join(format!(
        "muchswift_session_cli_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let report = dir.join("BENCH_session_test.json");
    let out = bin()
        .args([
            "cluster", "--n", "2000", "--d", "3", "--k", "4", "--backend", "cpu",
            "--session",
            "--report", report.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("session plane"), "{stdout}");
    let text = std::fs::read_to_string(&report).unwrap();
    assert!(text.contains("\"sessions\":0"), "{text}");
    assert!(text.contains("\"centroid_bcasts\":0"), "{text}");
    assert!(text.contains("\"remote_fallbacks\":0"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}
