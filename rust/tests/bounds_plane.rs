//! Bounds-plane acceptance tests (ISSUE 10): the triangle-inequality
//! pruning in the batched engine and the `Predictor` is *work
//! elimination, not approximation* — every surviving candidate is scored
//! by the same kernels, so a bounds-on run must be bitwise the bounds-off
//! run wherever the kernel itself is position-independent (scalar and
//! quantized tiers: labels AND distances), and label-identical on
//! separated data for the SIMD tier (whose per-candidate value bits
//! depend on the candidate's position in the list — DESIGN.md §10).
//!
//! Also pinned here: the duplicated-centroid tie rule (exact ties are
//! unprunable by construction, so the lowest-index winner survives), the
//! zero-movement fixpoint (a converged model keeps tight uppers and
//! prunes aggressively without drifting), and the `Auto` threshold.

use muchswift::data::synthetic::generate_params;
use muchswift::data::Dataset;
use muchswift::kdtree::KdTree;
use muchswift::kmeans::filtering::{self, FilterOpts, QuantPanels};
use muchswift::kmeans::init::{init_centroids, Init};
use muchswift::kmeans::panel::{CpuPanels, KernelKind, PanelKernel, ParCpuPanels};
use muchswift::kmeans::predict::Predictor;
use muchswift::kmeans::solver::{Algo, KmeansSpec, SolverCtx};
use muchswift::kmeans::{BoundsMode, Metric};

/// Bounds-off vs bounds-on batched runs over the same data/init, any
/// backend.  Returns (off, on) results.
fn run_pair<B: muchswift::kmeans::panel::PanelBackend>(
    n: usize,
    d: usize,
    k: usize,
    sigma: f32,
    metric: Metric,
    seed: u64,
    mk: impl Fn() -> B,
) -> (muchswift::kmeans::KmeansResult, muchswift::kmeans::KmeansResult) {
    let s = generate_params(n, d, k, sigma, 1.0, seed);
    let tree = KdTree::build(&s.data);
    let init = init_centroids(&s.data, k, Init::UniformSample, metric, seed ^ 5);
    let off = FilterOpts { metric, tol: 1e-6, max_iters: 15, bounds: BoundsMode::Off };
    let on = FilterOpts { bounds: BoundsMode::On, ..off.clone() };
    let a = filtering::run_batched(&s.data, &tree, &init, &off, &mut mk());
    let b = filtering::run_batched(&s.data, &tree, &init, &on, &mut mk());
    (a, b)
}

fn assert_bitwise(
    a: &muchswift::kmeans::KmeansResult,
    b: &muchswift::kmeans::KmeansResult,
    ctx: &str,
) {
    assert_eq!(a.assignments, b.assignments, "{ctx}: labels");
    for (x, y) in a.centroids.flat().iter().zip(b.centroids.flat()) {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: centroid bits");
    }
    assert_eq!(a.stats.iterations(), b.stats.iterations(), "{ctx}: iters");
    assert_eq!(a.stats.converged, b.stats.converged, "{ctx}: converged");
}

#[test]
fn training_parity_scalar_both_metrics_at_large_k() {
    // k = 64 is the Auto threshold: the production configuration the
    // bench gate measures.  Scalar backend → full bitwise parity.
    for metric in [Metric::Euclid, Metric::Manhattan] {
        let (off, on) = run_pair(3000, 4, 64, 0.05, metric, 31, || CpuPanels);
        assert_bitwise(&off, &on, &format!("scalar {metric:?}"));
        assert!(
            on.stats.bound_pruned_points + on.stats.bound_pruned_candidates > 0,
            "{metric:?}: bounds never fired at k=64"
        );
        assert!(on.stats.bounds_matrix_cost > 0, "{metric:?}");
        assert_eq!(off.stats.bound_pruned_points, 0, "off mode stays inert");
        assert!(
            on.stats.total_dist_evals() < off.stats.total_dist_evals(),
            "{metric:?}: pruning must eliminate kernel evals"
        );
    }
}

#[test]
fn training_parity_quantized_both_metrics() {
    // The i8 shortlist + exact re-score tier scores each candidate
    // independently, so shrinking the list cannot move any value bit:
    // full bitwise parity holds here too.
    for metric in [Metric::Euclid, Metric::Manhattan] {
        let (off, on) = run_pair(2000, 6, 64, 0.05, metric, 33, QuantPanels::new);
        assert_bitwise(&off, &on, &format!("quant {metric:?}"));
        assert!(on.stats.bound_pruned_points + on.stats.bound_pruned_candidates > 0);
    }
}

#[test]
fn training_parity_simd_labels_on_separated_data() {
    // The SIMD kernel's per-candidate value bits depend on the
    // candidate's lane position, so a shrunk list can flip a *near-tie*.
    // On well-separated planted clusters there are no near-ties and the
    // labels (hence centroid bits, which only read labels) must agree.
    for metric in [Metric::Euclid, Metric::Manhattan] {
        let (off, on) = run_pair(2000, 8, 64, 0.03, metric, 37, || {
            ParCpuPanels::with_kind(1, KernelKind::Simd)
        });
        assert_bitwise(&off, &on, &format!("simd {metric:?}"));
    }
}

#[test]
fn spec_level_bounds_thread_through_the_batched_solver() {
    // The same parity through the public solver spec — proves the CLI's
    // `--algo filter-batched --bounds on` path, not just the engine fn.
    let s = generate_params(2500, 5, 64, 0.08, 1.0, 41);
    // Scalar kernel tier: position-independent values, so the assertion
    // below can demand full bitwise equality (the solver's default tier
    // at workers > 1 is the blocked kernel, whose value bits shift with
    // candidate-list position — label-exact only on separated data).
    let base = KmeansSpec::new(64)
        .algo(Algo::FilterBatched)
        .kernel(KernelKind::Scalar)
        .seed(9)
        .max_iters(12);
    let off = base.clone().bounds(BoundsMode::Off).solve(&mut SolverCtx::new(&s.data));
    let on = base.bounds(BoundsMode::On).solve(&mut SolverCtx::new(&s.data));
    assert_bitwise(&off, &on, "spec");
    assert!(on.stats.bound_pruned_points + on.stats.bound_pruned_candidates > 0);
    // Auto at k = 64 engages too (the documented threshold).
    assert!(BoundsMode::Auto.enabled_for(64));
    assert!(!BoundsMode::Auto.enabled_for(63));
}

#[test]
fn duplicated_centroids_keep_the_lowest_index_winner() {
    // Exact ties are unprunable by construction (`surely_lt` is strict
    // with slack), so the first-wins tie rule survives pruning: points
    // sitting exactly between duplicated centers keep the lower label.
    let data = Dataset::from_flat(
        6,
        2,
        vec![0.0, 0.0, 0.1, 0.0, 5.0, 5.0, 5.1, 5.0, 0.0, 0.1, 5.0, 5.1],
    );
    let tree = KdTree::build(&data);
    // Centers 0 and 1 are bit-identical duplicates; center 2 is far away.
    let init = Dataset::from_flat(3, 2, vec![0.05, 0.05, 0.05, 0.05, 5.05, 5.05]);
    for metric in [Metric::Euclid, Metric::Manhattan] {
        let off = FilterOpts { metric, tol: 0.0, max_iters: 3, bounds: BoundsMode::Off };
        let on = FilterOpts { bounds: BoundsMode::On, ..off.clone() };
        let a = filtering::run_batched(&data, &tree, &init, &off, &mut CpuPanels);
        let b = filtering::run_batched(&data, &tree, &init, &on, &mut CpuPanels);
        assert_eq!(a.assignments, b.assignments, "{metric:?}");
        // Nobody may land on the duplicated higher index.
        assert!(
            b.assignments.iter().all(|&l| l != 1),
            "{metric:?}: duplicated center stole a point: {:?}",
            b.assignments
        );
    }
}

#[test]
fn zero_movement_fixpoint_prunes_without_drifting() {
    // Restart both modes from already-converged centroids: every shift
    // is exactly 0.0, uppers stay tight, and the second iteration must
    // prune while reproducing the fixpoint bit for bit.
    let s = generate_params(1500, 3, 64, 0.05, 1.0, 47);
    let tree = KdTree::build(&s.data);
    let init = init_centroids(&s.data, 64, Init::UniformSample, Metric::Euclid, 48);
    let warm = FilterOpts {
        metric: Metric::Euclid,
        tol: 1e-6,
        max_iters: 60,
        bounds: BoundsMode::Off,
    };
    let converged = filtering::run_batched(&s.data, &tree, &init, &warm, &mut CpuPanels);
    assert!(converged.stats.converged, "warmup did not converge");
    // Negative tolerance: zero movement must not early-out at iteration
    // 1, or the bounds state (seeded on its first advance) never
    // activates and the pruning claim below would be vacuous.
    let off = FilterOpts { tol: -1.0, max_iters: 3, ..warm };
    let on = FilterOpts { bounds: BoundsMode::On, ..off.clone() };
    let a = filtering::run_batched(&s.data, &tree, &converged.centroids, &off, &mut CpuPanels);
    let b = filtering::run_batched(&s.data, &tree, &converged.centroids, &on, &mut CpuPanels);
    assert_bitwise(&a, &b, "fixpoint");
    for (x, y) in b.centroids.flat().iter().zip(converged.centroids.flat()) {
        assert_eq!(x.to_bits(), y.to_bits(), "fixpoint drifted");
    }
    assert!(
        b.stats.bound_pruned_points > 0,
        "tight uppers at a fixpoint must prune points outright"
    );
}

// ---------------------------------------------------------------------------
// Predictor
// ---------------------------------------------------------------------------

fn small_model(k: usize, seed: u64) -> (muchswift::kmeans::model::KmeansModel, Dataset) {
    let s = generate_params(1200.max(k * 4), 6, k, 0.05, 1.0, seed);
    let spec = KmeansSpec::new(k).seed(seed).max_iters(25);
    let model = spec.fit(&mut SolverCtx::new(&s.data));
    (model, s.data)
}

#[test]
fn predictor_bounds_parity_scalar_and_quantized() {
    let (model, data) = small_model(64, 51);
    // Scalar panels: labels AND distances bitwise.
    let (l0, d0) = Predictor::with_backend(&model, ParCpuPanels::with_kernel(2, PanelKernel::Scalar))
        .assign_scored(&data);
    let mut on = Predictor::with_backend(&model, ParCpuPanels::with_kernel(2, PanelKernel::Scalar))
        .bounds(BoundsMode::On);
    assert!(on.bounding());
    let (l1, d1) = on.assign_scored(&data);
    assert_eq!(l0, l1, "scalar predictor labels");
    for (x, y) in d0.iter().zip(d1.iter()) {
        assert_eq!(x.to_bits(), y.to_bits(), "scalar predictor distance bits");
    }
    let bs = on.bounds_stats();
    assert!(bs.pruned_candidates > 0, "no candidates pruned at k=64");
    assert!(bs.matrix_cost > 0);

    // Quantized tier: same contract.
    let (ql0, qd0) = Predictor::quantized(&model).assign_scored(&data);
    let mut qon = Predictor::quantized(&model).bounds(BoundsMode::On);
    let (ql1, qd1) = qon.assign_scored(&data);
    assert_eq!(ql0, ql1, "quantized predictor labels");
    for (x, y) in qd0.iter().zip(qd1.iter()) {
        assert_eq!(x.to_bits(), y.to_bits(), "quantized predictor distance bits");
    }
    assert!(qon.bounds_stats().pruned_candidates > 0);
}

#[test]
fn predictor_bounds_compose_with_the_kd_tree_prune() {
    // Both pruners stacked: the kd-tree shortlist feeds the bounds
    // filter; labels must still match the plain predictor exactly.
    let (model, data) = small_model(64, 53);
    let plain = Predictor::with_backend(&model, CpuPanels).assign(&data);
    let both = Predictor::with_backend(&model, CpuPanels)
        .prune(true)
        .bounds(BoundsMode::On)
        .assign(&data);
    assert_eq!(plain, both);
}

#[test]
fn predictor_auto_threshold_tracks_k() {
    // Auto engages at exactly k = 64 (`bounds::AUTO_MIN_K`); On engages
    // regardless of k.
    let (m63, _) = small_model(63, 55);
    let (m64, _) = small_model(64, 56);
    assert!(!Predictor::new(&m63).bounds(BoundsMode::Auto).bounding());
    assert!(Predictor::new(&m64).bounds(BoundsMode::Auto).bounding());
    assert!(Predictor::new(&m63).bounds(BoundsMode::On).bounding());
    assert!(!Predictor::new(&m64).bounds(BoundsMode::Off).bounding());
}
