//! Property-based invariant tests across the coordinator's building
//! blocks (seeded in-crate property runner — see `util::proptest`).

use muchswift::data::synthetic::generate_params;
use muchswift::data::Dataset;
use muchswift::hw::engine::EventQueue;
use muchswift::hw::stream::{simulate, StreamParams};
use muchswift::kdtree::KdTree;
use muchswift::kmeans::filtering::{self, CpuPanels};
use muchswift::kmeans::panel::{PanelBackend, PanelJobs, PanelSet};
use muchswift::kmeans::init::{init_centroids, Init};
use muchswift::kmeans::twolevel::{combine, quarter, quarter_round_robin, QUARTERS};
use muchswift::kmeans::Metric;
use muchswift::util::proptest::proptest;
use muchswift::util::rng::Xoshiro256pp;

/// Both Quarter strategies produce a disjoint, complete partition with
/// rows faithful to the original data.
#[test]
fn prop_quarter_is_a_partition() {
    proptest(40, |g| {
        let n = g.size(1, 3000).max(1);
        let d = g.usize_in(1, 6);
        let s = generate_params(n, d, g.usize_in(1, 4), 0.3, 1.0, g.case as u64);
        let tree = KdTree::build(&s.data);
        for (parts, ids) in [quarter_round_robin(&s.data), quarter(&s.data, &tree)] {
            if parts.len() != QUARTERS {
                return Err(format!("expected {QUARTERS} parts, got {}", parts.len()));
            }
            let mut seen = vec![false; n];
            for (p, id) in parts.iter().zip(ids.iter()) {
                if p.len() != id.len() {
                    return Err("part/id length mismatch".into());
                }
                for (row, &orig) in id.iter().enumerate() {
                    if seen[orig as usize] {
                        return Err(format!("row {orig} appears twice"));
                    }
                    seen[orig as usize] = true;
                    if p.point(row) != s.data.point(orig as usize) {
                        return Err("gathered row differs from original".into());
                    }
                }
            }
            if !seen.iter().all(|&b| b) {
                return Err("partition drops rows".into());
            }
        }
        Ok(())
    });
}

/// Combine: each merged centroid lies inside the bounding box of its
/// source centroids, and total weight is conserved in the weighting.
#[test]
fn prop_combine_stays_in_hull_bbox() {
    proptest(60, |g| {
        let k = g.usize_in(1, 8);
        let d = g.usize_in(1, 5);
        let q = g.usize_in(1, 4);
        let mut rng = Xoshiro256pp::seed_from_u64(g.case as u64 ^ 0xBEEF);
        let cents: Vec<Dataset> = (0..q)
            .map(|_| {
                Dataset::from_flat(
                    k,
                    d,
                    (0..k * d).map(|_| rng.uniform_f32(-5.0, 5.0)).collect(),
                )
            })
            .collect();
        let counts: Vec<Vec<usize>> = (0..q)
            .map(|_| (0..k).map(|_| 1 + rng.below_usize(100)).collect())
            .collect();
        let merged = combine(&cents, &counts, Metric::Euclid);
        if merged.len() != k || merged.dims() != d {
            return Err("merged shape wrong".into());
        }
        // Global bbox over all source centroids bounds every merged point
        // (weighted means cannot escape the hull, hence not the bbox).
        let mut lo = vec![f32::INFINITY; d];
        let mut hi = vec![f32::NEG_INFINITY; d];
        for c in &cents {
            for p in c.iter() {
                for j in 0..d {
                    lo[j] = lo[j].min(p[j]);
                    hi[j] = hi[j].max(p[j]);
                }
            }
        }
        for p in merged.iter() {
            for j in 0..d {
                if p[j] < lo[j] - 1e-4 || p[j] > hi[j] + 1e-4 {
                    return Err(format!("merged coord {} outside bbox [{}, {}]", p[j], lo[j], hi[j]));
                }
            }
        }
        Ok(())
    });
}

/// The two filtering engines agree on counts/assignments for arbitrary
/// shapes, metrics and leaf sizes (single pass, identical inputs).
#[test]
fn prop_engines_agree() {
    proptest(25, |g| {
        let n = g.size(10, 800).max(10);
        let d = g.usize_in(1, 5);
        let k = g.usize_in(1, 7).min(n);
        let leaf = g.usize_in(1, 12);
        let metric = *g.pick(&[Metric::Euclid, Metric::Manhattan]);
        let s = generate_params(n, d, k, g.f32_in(0.05, 0.6), 1.5, g.case as u64);
        let tree = KdTree::build_with(&s.data, leaf);
        let init = init_centroids(&s.data, k, Init::UniformSample, metric, g.case as u64 ^ 3);
        let mut a1 = vec![0u32; n];
        let mut a2 = vec![0u32; n];
        let (_, c1, s1) = filtering::filter_iteration(&tree, &s.data, &init, metric, &mut a1);
        let (_, c2, s2) = filtering::filter_iteration_batched(
            &tree, &s.data, &init, metric, &mut CpuPanels, &mut a2,
        );
        if a1 != a2 {
            return Err(format!("assignments diverge (n={n} d={d} k={k} leaf={leaf})"));
        }
        if c1 != c2 {
            return Err("counts diverge".into());
        }
        if s1.dist_evals != s2.dist_evals || s1.prune_tests != s2.prune_tests {
            return Err("work counters diverge".into());
        }
        Ok(())
    });
}

/// Conservation through the filtering pass: counts sum to n, every point
/// assigned a valid cluster, interior+leaf assignment covers each point
/// exactly once.
#[test]
fn prop_filtering_conserves_points() {
    proptest(30, |g| {
        let n = g.size(5, 1500).max(5);
        let d = g.usize_in(1, 4);
        let k = g.usize_in(1, 6).min(n);
        let s = generate_params(n, d, k, 0.25, 1.0, g.case as u64 ^ 0x51);
        let tree = KdTree::build_with(&s.data, g.usize_in(1, 10));
        let init = init_centroids(&s.data, k, Init::UniformSample, Metric::Euclid, 1);
        let mut assign = vec![u32::MAX; n];
        let (_, counts, st) =
            filtering::filter_iteration(&tree, &s.data, &init, Metric::Euclid, &mut assign);
        if counts.iter().sum::<u32>() as usize != n {
            return Err(format!("counts sum {} != n {n}", counts.iter().sum::<u32>()));
        }
        if assign.iter().any(|&a| a as usize >= k) {
            return Err("unassigned or out-of-range point".into());
        }
        if st.leaf_points + st.interior_assigns != n as u64 {
            return Err(format!(
                "coverage: leaf {} + interior {} != {n}",
                st.leaf_points, st.interior_assigns
            ));
        }
        Ok(())
    });
}

/// The offload panel path (batching through a backend) is equivalent to
/// direct CPU computation for arbitrary ragged batches.
#[test]
fn prop_panel_backend_equivalence() {
    proptest(40, |g| {
        let d = g.usize_in(1, 8);
        let k = g.usize_in(1, 10);
        let jobs = g.size(1, 200).max(1);
        let mut rng = Xoshiro256pp::seed_from_u64(g.case as u64 ^ 0x77);
        let cents = Dataset::from_flat(
            k,
            d,
            (0..k * d).map(|_| rng.uniform_f32(-3.0, 3.0)).collect(),
        );
        let mut batch = PanelJobs::new();
        batch.clear(d);
        let mut mid = vec![0f32; d];
        for _ in 0..jobs {
            for m in mid.iter_mut() {
                *m = rng.uniform_f32(-3.0, 3.0);
            }
            let len = 1 + rng.below_usize(k);
            let mut c: Vec<u32> = (0..k as u32).collect();
            rng.shuffle(&mut c);
            c.truncate(len);
            batch.push(&mid, &c);
        }
        let metric = *g.pick(&[Metric::Euclid, Metric::Manhattan]);
        let mut got = PanelSet::new();
        CpuPanels.panels(&batch, &cents, metric, &mut got);
        for j in 0..batch.len() {
            let row = got.row(j);
            for (slot, &c) in batch.cands(j).iter().enumerate() {
                let want = metric.dist(batch.mid(j), cents.point(c as usize));
                if (row[slot] - want).abs() > 1e-5 * (1.0 + want.abs()) {
                    return Err(format!("panel mismatch job {j} cand {c}"));
                }
            }
        }
        Ok(())
    });
}

/// Stream pipeline: finish time is bounded below by both pure-producer
/// and pure-consumer times and above by their serial sum (+latency).
#[test]
fn prop_stream_bounds() {
    proptest(60, |g| {
        let total = (g.size(1, 1 << 22)).max(1) as u64;
        let prod = g.f32_in(0.5, 20.0) as f64 * 1e9;
        let cons = g.f32_in(0.5, 20.0) as f64 * 1e9;
        let fifo = 1024u64 << g.usize_in(0, 8);
        let p = StreamParams {
            total_bytes: total,
            burst_bytes: 1024.min(fifo),
            producer_bytes_per_s: prod,
            producer_latency_ps: g.usize_in(0, 1_000_000) as u64,
            consumer_bytes_per_s: cons,
            fifo_bytes: fifo,
        };
        let r = simulate(&p);
        let t_prod = total as f64 / prod * 1e12 + p.producer_latency_ps as f64;
        let t_cons = total as f64 / cons * 1e12;
        let lower = t_prod.max(t_cons) * 0.999;
        let upper = (t_prod + t_cons) * 1.001 + 1e6;
        let f = r.finish_ps as f64;
        if f < lower {
            return Err(format!("finish {f} below lower bound {lower}"));
        }
        if f > upper {
            return Err(format!("finish {f} above serial bound {upper}"));
        }
        if r.high_water_bytes > fifo {
            return Err("fifo overflow".into());
        }
        Ok(())
    });
}

/// DES event queue: arbitrary schedules pop in nondecreasing time order
/// with FIFO ties.
#[test]
fn prop_event_queue_ordering() {
    proptest(50, |g| {
        let mut q: EventQueue<usize> = EventQueue::new();
        let events = g.size(1, 500).max(1);
        let mut rng = Xoshiro256pp::seed_from_u64(g.case as u64);
        let mut expected: Vec<(u64, usize)> = Vec::new();
        for i in 0..events {
            let t = rng.below(1000);
            q.schedule(t, i);
            expected.push((t, i));
        }
        expected.sort_by_key(|&(t, i)| (t, i)); // seq == insertion order
        let mut got = Vec::new();
        let mut last = 0u64;
        while let Some((t, i)) = q.pop() {
            if t < last {
                return Err("time went backwards".into());
            }
            last = t;
            got.push((t, i));
        }
        if got != expected {
            return Err("pop order != (time, insertion) order".into());
        }
        Ok(())
    });
}
