//! ClusterService acceptance: concurrent multi-client predict batches
//! through the micro-batching dispatcher must be correct (identical to a
//! direct `Predictor` over the same model), fully accounted for in
//! `ServeMetrics`, and robust at the edges (empty requests, oversized
//! requests, dimension mismatches, shutdown draining).

use muchswift::data::synthetic::generate_params;
use muchswift::data::Dataset;
use muchswift::kmeans::panel::{KernelKind, PanelKernel, ParCpuPanels};
use muchswift::kmeans::predict::Predictor;
use muchswift::kmeans::solver::{KmeansSpec, SolverCtx};
use muchswift::kmeans::KmeansModel;
use muchswift::serve::{ClusterService, ServeConfig, ServeError};
use std::sync::Arc;

fn trained_model(n: usize, d: usize, k: usize, seed: u64) -> Arc<KmeansModel> {
    let s = generate_params(n, d, k, 0.2, 2.0, seed);
    Arc::new(KmeansSpec::new(k).seed(seed).fit(&mut SolverCtx::new(&s.data)))
}

fn slice(data: &Dataset, start: usize, len: usize) -> Dataset {
    let d = data.dims();
    Dataset::from_flat(len, d, data.flat()[start * d..(start + len) * d].to_vec())
}

#[test]
fn concurrent_clients_get_exactly_direct_predictor_answers() {
    let model = trained_model(2000, 4, 6, 17);
    let queries = generate_params(1280, 4, 6, 0.5, 2.0, 91).data;
    // Ground truth from a direct predictor with the same kernel.
    let want = Predictor::with_backend(
        model.as_ref(),
        ParCpuPanels::with_kernel(2, PanelKernel::Blocked),
    )
    .assign(&queries);

    let svc = ClusterService::start(
        Arc::clone(&model),
        ServeConfig {
            workers: 2,
            max_batch_points: 128, // small budget → several batches
            queue_cap: 64,
            kernel: KernelKind::Blocked,
            prune: None,
            ..Default::default()
        },
    );
    let clients = 4usize;
    let per_client = 8usize;
    let req_len = 40usize;
    std::thread::scope(|scope| {
        for c in 0..clients {
            let svc = &svc;
            let queries = &queries;
            let want = &want;
            scope.spawn(move || {
                for r in 0..per_client {
                    let start = (c * per_client + r) * req_len;
                    let reply = svc.predict(slice(queries, start, req_len)).unwrap();
                    assert_eq!(reply.labels.len(), req_len);
                    assert_eq!(reply.distances.len(), req_len);
                    assert!(reply.batched_with >= 1);
                    assert_eq!(
                        reply.labels,
                        want[start..start + req_len],
                        "client {c} request {r}"
                    );
                }
            });
        }
    });
    let m = svc.shutdown();
    let total_reqs = (clients * per_client) as u64;
    assert_eq!(m.requests, total_reqs);
    assert_eq!(m.points, total_reqs * req_len as u64);
    assert!(m.batches >= 1 && m.batches <= total_reqs);
    // The point budget caps coalescing: never more than 3 x 40-pt
    // requests (128 / 40) in one batch.
    assert!(m.max_batch_requests <= 3, "max_batch_requests {}", m.max_batch_requests);
    assert!(m.max_batch_points <= 128 + req_len as u64);
    assert!(m.mean_batch_requests >= 1.0);
    assert!(m.throughput_pps > 0.0);
    assert!(m.latency_p99_ms >= m.latency_p50_ms);
    assert!(m.wall_s > 0.0 && m.busy_s >= 0.0);
}

#[test]
fn oversized_and_empty_requests_are_served() {
    let model = trained_model(600, 3, 4, 5);
    let queries = generate_params(500, 3, 4, 0.4, 1.0, 7).data;
    let svc = ClusterService::start(
        Arc::clone(&model),
        ServeConfig {
            max_batch_points: 64, // request below is 8x the budget
            ..Default::default()
        },
    );
    // Oversized request ships alone and completely.
    let reply = svc.predict(slice(&queries, 0, 500)).unwrap();
    assert_eq!(reply.labels.len(), 500);
    assert_eq!(reply.batched_with, 1);
    // Empty request resolves to empty labels.
    let reply = svc.predict(Dataset::from_flat(0, 3, vec![])).unwrap();
    assert!(reply.labels.is_empty());
    let m = svc.shutdown();
    assert_eq!(m.requests, 2);
    assert_eq!(m.points, 500);
}

#[test]
fn dim_mismatch_is_rejected_eagerly() {
    let model = trained_model(400, 3, 3, 2);
    let svc = ClusterService::start(Arc::clone(&model), ServeConfig::default());
    let bad = Dataset::from_flat(2, 5, vec![0.0; 10]);
    match svc.submit(bad) {
        Err(ServeError::DimMismatch { expected, got }) => {
            assert_eq!(expected, 3);
            assert_eq!(got, 5);
        }
        other => panic!("expected DimMismatch, got {:?}", other.err()),
    }
    // The service is still healthy afterwards.
    let ok = svc.predict(Dataset::from_flat(1, 3, vec![0.0; 3])).unwrap();
    assert_eq!(ok.labels.len(), 1);
}

#[test]
fn shutdown_drains_accepted_requests() {
    let model = trained_model(800, 3, 4, 9);
    let queries = generate_params(256, 3, 4, 0.3, 1.0, 4).data;
    let svc = ClusterService::start(Arc::clone(&model), ServeConfig::default());
    // Fire-and-hold a burst of tickets, then shut down immediately: every
    // accepted request must still be answered (drain-before-exit).
    let tickets: Vec<_> = (0..16)
        .map(|i| svc.submit(slice(&queries, i * 16, 16)).unwrap())
        .collect();
    let metrics = svc.shutdown();
    for t in tickets {
        let reply = t.wait().unwrap();
        assert_eq!(reply.labels.len(), 16);
    }
    assert_eq!(metrics.requests, 16);
    assert_eq!(metrics.points, 256);
}

#[test]
fn deadline_batcher_coalesces_a_trickle_into_one_batch() {
    // With a generous deadline and budget, requests submitted over a few
    // milliseconds must ride one panel batch instead of draining one by
    // one — the ROADMAP's "wait up to T µs to coalesce more" batcher.
    let model = trained_model(600, 3, 4, 11);
    let queries = generate_params(64, 3, 4, 0.4, 1.0, 8).data;
    let svc = ClusterService::start(
        Arc::clone(&model),
        ServeConfig {
            batch_deadline_us: 200_000, // 200 ms — far beyond the submit loop below
            max_batch_points: 4096,
            ..Default::default()
        },
    );
    let tickets: Vec<_> = (0..4)
        .map(|i| svc.submit(slice(&queries, i * 16, 16)).unwrap())
        .collect();
    for t in tickets {
        let reply = t.wait().unwrap();
        assert_eq!(reply.labels.len(), 16);
        assert_eq!(reply.batched_with, 4, "deadline batcher must coalesce all 4");
    }
    let m = svc.shutdown();
    assert_eq!(m.requests, 4);
    assert_eq!(m.batches, 1);
}

#[test]
fn deadline_batcher_ships_early_when_the_budget_fills() {
    // A full point budget must not sit out the deadline.
    let model = trained_model(600, 3, 4, 11);
    let queries = generate_params(64, 3, 4, 0.4, 1.0, 8).data;
    let svc = ClusterService::start(
        Arc::clone(&model),
        ServeConfig {
            batch_deadline_us: 10_000_000, // 10 s: a waited-out deadline would hang the test
            max_batch_points: 32,
            ..Default::default()
        },
    );
    let tickets: Vec<_> = (0..2)
        .map(|i| svc.submit(slice(&queries, i * 16, 16)).unwrap())
        .collect();
    for t in tickets {
        assert_eq!(t.wait().unwrap().labels.len(), 16);
    }
    let m = svc.shutdown();
    assert_eq!(m.requests, 2);
}

#[test]
fn warm_reload_swaps_models_between_batches() {
    // Same dims, different k: replies before the reload come from model A,
    // replies after from model B — scalar kernel, so both are bit-exact
    // against direct predictors.
    let model_a = trained_model(1200, 4, 4, 31);
    let model_b = trained_model(1400, 4, 6, 77);
    let queries = generate_params(200, 4, 5, 0.5, 2.0, 12).data;
    let want_a = Predictor::new(model_a.as_ref()).assign(&queries);
    let want_b = Predictor::new(model_b.as_ref()).assign(&queries);
    assert_ne!(want_a, want_b, "models must be distinguishable for this test");

    let svc = ClusterService::start(
        Arc::clone(&model_a),
        ServeConfig {
            kernel: KernelKind::Scalar,
            ..Default::default()
        },
    );
    let r = svc.predict(queries.clone()).unwrap();
    assert_eq!(r.labels, want_a);

    // Dim mismatch is rejected and leaves the old model serving.
    let bad = trained_model(500, 7, 3, 5);
    match svc.reload(Arc::clone(&bad)) {
        Err(ServeError::DimMismatch { expected, got }) => {
            assert_eq!(expected, 4);
            assert_eq!(got, 7);
        }
        other => panic!("expected DimMismatch, got {:?}", other.err()),
    }
    assert_eq!(svc.model().k(), 4);

    svc.reload(Arc::clone(&model_b)).unwrap();
    assert_eq!(svc.model().k(), 6);
    let r = svc.predict(queries.clone()).unwrap();
    assert_eq!(r.labels, want_b);
}

#[test]
fn in_flight_tickets_complete_against_a_consistent_model() {
    // Fire a stream of tickets while reloading mid-stream: every reply
    // must match model A's or model B's answer *entirely* — a batch is
    // never split across models — and nothing is dropped.
    let model_a = trained_model(1200, 4, 4, 31);
    let model_b = trained_model(1400, 4, 6, 77);
    let queries = generate_params(640, 4, 5, 0.5, 2.0, 12).data;
    let want_a = Predictor::new(model_a.as_ref()).assign(&queries);
    let want_b = Predictor::new(model_b.as_ref()).assign(&queries);

    let svc = ClusterService::start(
        Arc::clone(&model_a),
        ServeConfig {
            kernel: KernelKind::Scalar,
            max_batch_points: 32, // several batches across the burst
            ..Default::default()
        },
    );
    let reqs = 20usize;
    let req_len = 32usize;
    let mut tickets = Vec::new();
    for i in 0..reqs {
        tickets.push((i, svc.submit(slice(&queries, i * req_len, req_len)).unwrap()));
        if i == reqs / 2 {
            svc.reload(Arc::clone(&model_b)).unwrap();
        }
    }
    let mut from_b = 0usize;
    for (i, t) in tickets {
        let reply = t.wait().unwrap();
        let lo = i * req_len;
        let hi = lo + req_len;
        let is_a = reply.labels == want_a[lo..hi];
        let is_b = reply.labels == want_b[lo..hi];
        assert!(
            is_a || is_b,
            "request {i}: reply matches neither model wholesale"
        );
        if is_b {
            from_b += 1;
        }
    }
    let m = svc.shutdown();
    assert_eq!(m.requests, reqs as u64);
    // The tail of the burst was submitted after the swap, so at least one
    // batch must have run on model B.
    assert!(from_b >= 1, "reload never took effect");
}

#[test]
fn multi_dispatcher_sharding_serves_correctly() {
    // P dispatcher panels drain the shared queue concurrently; answers
    // stay bit-exact (scalar kernel) and fully accounted for.
    let model = trained_model(1500, 4, 8, 3);
    let queries = generate_params(1280, 4, 8, 0.5, 2.0, 41).data;
    let want = Predictor::new(model.as_ref()).assign(&queries);
    let svc = ClusterService::start(
        Arc::clone(&model),
        ServeConfig {
            dispatchers: 3,
            workers: 3,
            kernel: KernelKind::Scalar,
            max_batch_points: 64,
            ..Default::default()
        },
    );
    let clients = 4usize;
    let per_client = 10usize;
    let req_len = 32usize;
    std::thread::scope(|scope| {
        for c in 0..clients {
            let svc = &svc;
            let queries = &queries;
            let want = &want;
            scope.spawn(move || {
                for r in 0..per_client {
                    let start = (c * per_client + r) * req_len;
                    let reply = svc.predict(slice(queries, start, req_len)).unwrap();
                    assert_eq!(reply.labels, want[start..start + req_len]);
                }
            });
        }
    });
    let m = svc.shutdown();
    assert_eq!(m.requests, (clients * per_client) as u64);
    assert_eq!(m.points, (clients * per_client * req_len) as u64);
}

#[test]
fn multi_dispatcher_shutdown_drains_accepted_requests() {
    let model = trained_model(800, 3, 4, 9);
    let queries = generate_params(256, 3, 4, 0.3, 1.0, 4).data;
    let svc = ClusterService::start(
        Arc::clone(&model),
        ServeConfig {
            dispatchers: 2,
            ..Default::default()
        },
    );
    let tickets: Vec<_> = (0..16)
        .map(|i| svc.submit(slice(&queries, i * 16, 16)).unwrap())
        .collect();
    let metrics = svc.shutdown();
    for t in tickets {
        assert_eq!(t.wait().unwrap().labels.len(), 16);
    }
    assert_eq!(metrics.requests, 16);
    assert_eq!(metrics.points, 256);
}

#[test]
fn saturated_queue_sheds_deadline_submits_as_rejected() {
    // queue_cap=1 with a long hold deadline: the dispatcher's
    // micro-batcher keeps the first request parked in the queue for the
    // hold window, so a second submit finds the queue full for long
    // enough that a 50 ms admission deadline must expire.
    let model = trained_model(600, 3, 4, 11);
    let queries = generate_params(64, 3, 4, 0.4, 1.0, 8).data;
    let svc = ClusterService::start(
        Arc::clone(&model),
        ServeConfig {
            queue_cap: 1,
            batch_deadline_us: 300_000, // 300 ms hold >> 50 ms admission deadline
            ..Default::default()
        },
    );
    let first = svc.submit(slice(&queries, 0, 16)).unwrap();
    match svc.submit_timeout(slice(&queries, 16, 16), std::time::Duration::from_millis(50)) {
        Err(ServeError::Rejected) => {}
        other => panic!("expected Rejected, got {:?}", other.err()),
    }
    // The accepted request still completes, and the service keeps
    // serving after shedding load.
    assert_eq!(first.wait().unwrap().labels.len(), 16);
    let reply = svc.predict(slice(&queries, 32, 16)).unwrap();
    assert_eq!(reply.labels.len(), 16);
    let m = svc.shutdown();
    assert_eq!(m.rejected, 1, "the shed request must be counted");
    assert_eq!(m.requests, 2, "rejected submits never count as fulfilled");
}

#[test]
fn submit_timeout_admits_when_there_is_room() {
    // With a roomy queue, submit_timeout behaves exactly like submit.
    let model = trained_model(600, 3, 4, 11);
    let queries = generate_params(32, 3, 4, 0.4, 1.0, 8).data;
    let svc = ClusterService::start(Arc::clone(&model), ServeConfig::default());
    let t = svc
        .submit_timeout(slice(&queries, 0, 16), std::time::Duration::from_millis(500))
        .unwrap();
    assert_eq!(t.wait().unwrap().labels.len(), 16);
    let m = svc.shutdown();
    assert_eq!(m.rejected, 0);
    assert_eq!(m.requests, 1);
}

#[test]
fn scalar_service_is_bit_identical_to_oracle_predictor() {
    // Scalar kernel end to end: the service must agree with the
    // training-side arg-min arithmetic exactly, including distances.
    let model = trained_model(1000, 5, 8, 13);
    let queries = generate_params(300, 5, 8, 0.5, 2.0, 3).data;
    let (want_labels, want_dists) = Predictor::new(model.as_ref()).assign_scored(&queries);
    let svc = ClusterService::start(
        Arc::clone(&model),
        ServeConfig {
            kernel: KernelKind::Scalar,
            ..Default::default()
        },
    );
    let reply = svc.predict(queries.clone()).unwrap();
    assert_eq!(reply.labels, want_labels);
    assert_eq!(reply.distances, want_dists);
}

#[test]
fn quantized_service_matches_oracle_bitwise_and_counts_candidates() {
    // The i8 shortlist + exact-f32 rescore path: labels AND assigned
    // distances must be bit-identical to the scalar oracle, and the
    // kernel telemetry must account for the quantized/rescored split.
    let model = trained_model(1000, 5, 8, 13);
    let queries = generate_params(300, 5, 8, 0.5, 2.0, 3).data;
    let (want_labels, want_dists) = Predictor::new(model.as_ref()).assign_scored(&queries);
    let svc = ClusterService::start(
        Arc::clone(&model),
        ServeConfig {
            quantized: true,
            ..Default::default()
        },
    );
    let reply = svc.predict(queries.clone()).unwrap();
    assert_eq!(reply.labels, want_labels);
    assert_eq!(reply.distances, want_dists);
    let m = svc.shutdown();
    assert!(m.quantized_candidates > 0, "i8 path never engaged");
    assert!(m.rescored_candidates >= 1, "the winner is always re-scored exactly");
    assert!(m.rescored_candidates <= m.quantized_candidates);
}
