//! Property tests pinning the panel engine (ISSUE 1 acceptance):
//!
//! 1. `ParCpuPanels` (scalar + blocked kernels, 1..4 workers) produces
//!    panels equal to the scalar `CpuPanels` oracle within 1e-4 for both
//!    metrics on arbitrary ragged batches.
//! 2. `filter_iteration_batched` driven by the blocked multi-threaded
//!    backend still matches `filter_iteration` (the recursive reference)
//!    and a hand-rolled Lloyd step on assignments and objective for random
//!    datasets with odd dims (d ∈ {1, 3, 7, 15}) — any assignment
//!    disagreement must be a genuine floating-point tie.
//!
//! The scratch arenas are deliberately shared across property cases to
//! exercise the recycle path (`FilterScratch` reuse across runs).

use muchswift::data::synthetic::generate_params;
use muchswift::data::Dataset;
use muchswift::kdtree::KdTree;
use muchswift::kmeans::filtering::{self, FilterScratch};
use muchswift::kmeans::init::{init_centroids, Init};
use muchswift::kmeans::panel::{
    CpuPanels, KernelKind, PanelBackend, PanelJobs, PanelKernel, PanelSet, ParCpuPanels,
};
use muchswift::kmeans::Metric;
use muchswift::util::proptest::proptest;
use muchswift::util::rng::Xoshiro256pp;
use std::cell::RefCell;

#[test]
fn prop_par_and_blocked_panels_match_scalar_oracle() {
    proptest(60, |g| {
        let d = *g.pick(&[1usize, 2, 3, 7, 8, 15, 16]);
        let k = g.usize_in(1, 24);
        let jobs_n = g.size(1, 400).max(1);
        let metric = *g.pick(&[Metric::Euclid, Metric::Manhattan]);
        let workers = *g.pick(&[1usize, 2, 4]);
        let kernel = *g.pick(&[PanelKernel::Scalar, PanelKernel::Blocked]);

        let mut rng = Xoshiro256pp::seed_from_u64(g.case as u64 ^ 0x00A7_E155);
        let cents = Dataset::from_flat(
            k,
            d,
            (0..k * d).map(|_| rng.uniform_f32(-3.0, 3.0)).collect(),
        );
        let mut jobs = PanelJobs::new();
        jobs.clear(d);
        let mut mid = vec![0f32; d];
        for _ in 0..jobs_n {
            for m in mid.iter_mut() {
                *m = rng.uniform_f32(-3.0, 3.0);
            }
            let len = 1 + rng.below_usize(k);
            let mut c: Vec<u32> = (0..k as u32).collect();
            rng.shuffle(&mut c);
            c.truncate(len);
            jobs.push(&mid, &c);
        }

        let mut want = PanelSet::new();
        CpuPanels.begin_pass(&cents, metric);
        CpuPanels.panels(&jobs, &cents, metric, &mut want);

        let mut par = ParCpuPanels::with_kernel(workers, kernel);
        par.begin_pass(&cents, metric);
        let mut got = PanelSet::new();
        par.panels(&jobs, &cents, metric, &mut got);

        for j in 0..jobs.len() {
            let (a, b) = (want.row(j), got.row(j));
            if a.len() != b.len() {
                return Err(format!("row {j} length {} vs {}", a.len(), b.len()));
            }
            for (slot, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                if kernel == PanelKernel::Scalar {
                    if x != y {
                        return Err(format!(
                            "scalar kernel must be exact: job {j} slot {slot}: {x} vs {y}"
                        ));
                    }
                } else if (x - y).abs() > 1e-4 * (1.0 + x.abs()) {
                    return Err(format!(
                        "blocked kernel drift: job {j} slot {slot} ({metric:?} d={d}): {x} vs {y}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_blocked_parallel_engine_matches_recursive_and_lloyd() {
    let scratch = RefCell::new(FilterScratch::new());
    proptest(40, |g| {
        let d = *g.pick(&[1usize, 3, 7, 15]);
        let n = g.size(30, 600).max(30);
        let k = g.usize_in(1, 8).min(n);
        let metric = *g.pick(&[Metric::Euclid, Metric::Manhattan]);
        let workers = *g.pick(&[1usize, 2, 4]);
        let s = generate_params(n, d, k, g.f32_in(0.05, 0.5), 1.0, g.case as u64 ^ 0x9D);
        let tree = KdTree::build_with(&s.data, g.usize_in(1, 10));
        let init = init_centroids(&s.data, k, Init::UniformSample, metric, g.case as u64 ^ 3);

        // Reference: recursive engine (scalar arithmetic).
        let mut a_ref = vec![0u32; n];
        let (_, counts_ref, st_ref) =
            filtering::filter_iteration(&tree, &s.data, &init, metric, &mut a_ref);

        // Engine under test: blocked kernels across threads, recycled
        // arenas.
        let mut backend = ParCpuPanels::with_kernel(workers, PanelKernel::Blocked);
        let mut a_blk = vec![0u32; n];
        let (_, counts_blk, st_blk) = filtering::filter_iteration_batched_scratch(
            &tree,
            &s.data,
            &init,
            metric,
            &mut backend,
            &mut a_blk,
            &mut scratch.borrow_mut(),
        );

        if counts_ref.iter().sum::<u32>() != n as u32
            || counts_blk.iter().sum::<u32>() != n as u32
        {
            return Err("counts do not conserve points".into());
        }
        if st_ref.leaf_points + st_ref.interior_assigns != n as u64 {
            return Err("reference engine coverage broken".into());
        }
        if st_blk.leaf_points + st_blk.interior_assigns != n as u64 {
            return Err("blocked engine coverage broken".into());
        }

        // Any assignment disagreement must be a floating-point tie: the
        // two centroids are equidistant from the point up to f32 rounding.
        let mut obj_ref = 0f64;
        let mut obj_blk = 0f64;
        let mut obj_lloyd = 0f64;
        for (i, p) in s.data.iter().enumerate() {
            let dr = metric.dist(p, init.point(a_ref[i] as usize));
            let db = metric.dist(p, init.point(a_blk[i] as usize));
            obj_ref += dr as f64;
            obj_blk += db as f64;
            let (_, best_d) =
                muchswift::kmeans::metrics::nearest(metric, p, init.flat(), k, d);
            obj_lloyd += best_d as f64;
            if a_ref[i] != a_blk[i] && (dr - db).abs() > 1e-3 * (1.0 + dr.abs().min(db.abs())) {
                return Err(format!(
                    "point {i} ({metric:?} d={d} k={k} w={workers}): engines disagree \
                     beyond tie tolerance: ref c{} at {dr} vs blk c{} at {db}",
                    a_ref[i], a_blk[i]
                ));
            }
        }
        // Both engines must realize the Lloyd-step objective (exact
        // nearest assignment) up to rounding.
        for (name, obj) in [("recursive", obj_ref), ("blocked", obj_blk)] {
            if (obj - obj_lloyd).abs() > 1e-3 * (1.0 + obj_lloyd.abs()) {
                return Err(format!(
                    "{name} objective {obj} vs lloyd {obj_lloyd} (d={d} k={k})"
                ));
            }
        }
        Ok(())
    });
}

/// Full-run equivalence: iterating the blocked multi-threaded engine to
/// convergence stays on the recursive reference's trajectory.
#[test]
fn blocked_parallel_full_run_tracks_reference() {
    for (metric, d) in [(Metric::Euclid, 15), (Metric::Manhattan, 7)] {
        let s = generate_params(1200, d, 6, 0.15, 1.0, 21);
        let tree = KdTree::build(&s.data);
        let init = init_centroids(&s.data, 6, Init::UniformSample, metric, 4);
        let opts = filtering::FilterOpts {
            metric,
            tol: 1e-6,
            max_iters: 25,
        };
        let a = filtering::run(&s.data, &tree, &init, &opts);
        let mut backend = ParCpuPanels::new(4);
        let b = filtering::run_batched(&s.data, &tree, &init, &opts, &mut backend);
        let obj_a = a.objective(&s.data, metric);
        let obj_b = b.objective(&s.data, metric);
        assert!(
            (obj_a - obj_b).abs() <= 0.02 * (1.0 + obj_a.abs()),
            "{metric:?}: objective {obj_a} vs {obj_b}"
        );
        let same = a
            .assignments
            .iter()
            .zip(b.assignments.iter())
            .filter(|(x, y)| x == y)
            .count();
        assert!(same >= 1080, "{metric:?}: assignments diverge: {same}/1200");
    }
}

/// SIMD tier vs. the scalar oracle: relative error <= 1e-4 across dims
/// that straddle the vector widths (8-lane AVX2, 4-lane NEON) and ragged
/// candidate tails that don't divide the 4-candidate blocking.  On hosts
/// without a supported feature set `with_kind` demotes to blocked, so the
/// pin runs (and still holds) everywhere CI does.
#[test]
fn prop_simd_panels_match_scalar_oracle() {
    proptest(60, |g| {
        let d = *g.pick(&[1usize, 3, 7, 8, 15, 16, 64]);
        let k = g.usize_in(1, 24);
        let jobs_n = g.size(1, 300).max(1);
        let metric = *g.pick(&[Metric::Euclid, Metric::Manhattan]);
        let workers = *g.pick(&[1usize, 2, 4]);
        let kind = *g.pick(&[KernelKind::Simd, KernelKind::Auto]);

        let mut rng = Xoshiro256pp::seed_from_u64(g.case as u64 ^ 0x51D0_C0DE);
        let cents = Dataset::from_flat(
            k,
            d,
            (0..k * d).map(|_| rng.uniform_f32(-3.0, 3.0)).collect(),
        );
        let mut jobs = PanelJobs::new();
        jobs.clear(d);
        let mut mid = vec![0f32; d];
        for _ in 0..jobs_n {
            for m in mid.iter_mut() {
                *m = rng.uniform_f32(-3.0, 3.0);
            }
            let len = 1 + rng.below_usize(k);
            let mut c: Vec<u32> = (0..k as u32).collect();
            rng.shuffle(&mut c);
            c.truncate(len);
            jobs.push(&mid, &c);
        }

        let mut want = PanelSet::new();
        CpuPanels.begin_pass(&cents, metric);
        CpuPanels.panels(&jobs, &cents, metric, &mut want);

        let mut simd = ParCpuPanels::with_kind(workers, kind);
        simd.begin_pass(&cents, metric);
        let mut got = PanelSet::new();
        simd.panels(&jobs, &cents, metric, &mut got);

        for j in 0..jobs.len() {
            let (a, b) = (want.row(j), got.row(j));
            if a.len() != b.len() {
                return Err(format!("row {j} length {} vs {}", a.len(), b.len()));
            }
            for (slot, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                if (x - y).abs() > 1e-4 * (1.0 + x.abs()) {
                    return Err(format!(
                        "simd drift: job {j} slot {slot} ({metric:?} d={d}): {x} vs {y}"
                    ));
                }
            }
        }
        Ok(())
    });
}
