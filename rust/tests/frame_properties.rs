//! Property-style tests for the wire substrate of the remote shard
//! plane: random protocol messages of *every* kind must round-trip the
//! frame codec exactly, any single corrupted byte of a valid frame must
//! be refused (an `Err`, never a panic and never a silent success), and
//! a stream cut at *every* possible boundary must read as `Truncated`.
//!
//! These pins are what make the chaos-proxy faults (`util::fault`)
//! meaningful: corrupt/truncate injections are guaranteed to surface as
//! clean decode errors the retry ladder can act on.

use muchswift::data::Dataset;
use muchswift::kmeans::init::Init;
use muchswift::kmeans::remote::protocol::{
    dataset_checksum, CentroidsFrame, DoneFrame, IterFrame, LoadShardFrame, Message, PartialsFrame,
    ShardJob, WireSpec, KIND_CENTROIDS, KIND_DONE, KIND_END_SESSION, KIND_ERROR, KIND_HELLO,
    KIND_HELLO_ACK, KIND_ITER, KIND_JOB, KIND_LOAD_ACK, KIND_LOAD_SHARD, KIND_PARTIALS, KIND_PING,
    KIND_PONG, KIND_RELEASE, KIND_RELEASED, KIND_SHUTDOWN, PROTOCOL_VERSION,
};
use muchswift::kmeans::{IterStats, LevelWork, Metric, RunStats};
use muchswift::util::frame::FrameError;
use muchswift::util::proptest::{proptest_seeded, Gen};
use muchswift::util::rng::Xoshiro256pp;
use std::io::Cursor;

// ---------------------------------------------------------------------------
// Random message generators (seeded, deterministic)
// ---------------------------------------------------------------------------

fn random_dataset(g: &mut Gen, max_n: usize, max_d: usize) -> Dataset {
    let n = g.usize_in(1, max_n);
    let d = g.usize_in(1, max_d);
    let flat = g.vec_f32(n * d, -100.0, 100.0);
    Dataset::from_flat(n, d, flat)
}

fn random_level(g: &mut Gen) -> LevelWork {
    LevelWork {
        interior_jobs: g.rng.next_u64() >> 40,
        leaf_jobs: g.rng.next_u64() >> 40,
        cand_evals: g.rng.next_u64() >> 40,
        prune_tests: g.rng.next_u64() >> 40,
    }
}

fn random_iter_stats(g: &mut Gen) -> IterStats {
    let nlevels = g.usize_in(0, 3);
    IterStats {
        dist_evals: g.rng.next_u64() >> 32,
        node_visits: g.rng.next_u64() >> 32,
        leaf_points: g.rng.next_u64() >> 32,
        interior_assigns: g.rng.next_u64() >> 32,
        prune_tests: g.rng.next_u64() >> 32,
        moved: g.f32_in(0.0, 10.0),
        cost: if g.bool() {
            Some(g.f32_in(0.0, 1000.0) as f64)
        } else {
            None
        },
        levels: (0..nlevels).map(|_| random_level(g)).collect(),
    }
}

fn random_wire_spec(g: &mut Gen) -> WireSpec {
    WireSpec {
        k: g.usize_in(1, 16) as u32,
        metric: *g.pick(&[Metric::Euclid, Metric::Manhattan]),
        tol: g.f32_in(0.0, 1e-2),
        max_iters: g.usize_in(1, 500) as u64,
        init: *g.pick(&[Init::UniformSample, Init::KmeansPlusPlus]),
        seed: g.rng.next_u64(),
    }
}

/// One random message of each protocol kind, indexed 0..KINDS.
const KINDS: usize = 16;

fn random_message(g: &mut Gen, which: usize) -> Message {
    match which {
        0 => Message::Hello {
            version: if g.bool() {
                PROTOCOL_VERSION
            } else {
                g.rng.next_u64() as u32
            },
        },
        1 => Message::HelloAck {
            version: g.rng.next_u64() as u32,
        },
        2 => Message::Job(Box::new(ShardJob {
            shard: g.usize_in(0, 64) as u32,
            spec: random_wire_spec(g),
            data: random_dataset(g, 12, 4),
        })),
        3 => Message::Iter(Box::new(IterFrame {
            iter: g.usize_in(0, 1000) as u64,
            stats: random_iter_stats(g),
            centroids: random_dataset(g, 6, 3),
        })),
        4 => Message::Done(Box::new(DoneFrame {
            centroids: random_dataset(g, 6, 3),
            counts: (0..g.usize_in(1, 6)).map(|_| g.usize_in(0, 10_000)).collect(),
            stats: RunStats {
                converged: g.bool(),
                early_stopped: g.bool(),
                iters: (0..g.usize_in(0, 4)).map(|_| random_iter_stats(g)).collect(),
                // Kernel-tier counters are local-only (not wire-carried),
                // so the round-trip generator leaves them at zero.
                ..RunStats::default()
            },
        })),
        5 => Message::Error {
            code: g.usize_in(0, 255) as u8,
            message: format!("err-{}", g.rng.next_u64()),
        },
        6 => Message::Shutdown,
        7 => Message::Ping,
        8 => Message::Pong,
        // Session plane (v3).
        9 => {
            let data = random_dataset(g, 12, 4);
            // Honest checksum half the time — the codec round-trips
            // either way (validation is the server's job, not decode's).
            let checksum = if g.bool() {
                dataset_checksum(&data)
            } else {
                g.rng.next_u64() as u32
            };
            Message::LoadShard(Box::new(LoadShardFrame {
                shard: g.usize_in(0, 64) as u32,
                metric: *g.pick(&[Metric::Euclid, Metric::Manhattan]),
                checksum,
                data,
            }))
        }
        10 => Message::LoadAck {
            shard: g.usize_in(0, 64) as u32,
            checksum: g.rng.next_u64() as u32,
        },
        11 => Message::Centroids(Box::new(CentroidsFrame {
            shard: g.usize_in(0, 64) as u32,
            iter: g.usize_in(0, 1000) as u64,
            centroids: random_dataset(g, 6, 3),
        })),
        12 => {
            let k = g.usize_in(1, 6);
            let d = g.usize_in(1, 3);
            Message::Partials(Box::new(PartialsFrame {
                shard: g.usize_in(0, 64) as u32,
                iter: g.usize_in(0, 1000) as u64,
                sums: Dataset::from_flat(k, d, g.vec_f32(k * d, -100.0, 100.0)),
                counts: (0..k).map(|_| g.rng.next_u64() as u32).collect(),
                stats: random_iter_stats(g),
            }))
        }
        13 => Message::Release {
            shard: g.usize_in(0, 64) as u32,
        },
        14 => Message::Released {
            shard: g.usize_in(0, 64) as u32,
        },
        _ => Message::EndSession,
    }
}

fn wire_of(msg: &Message) -> Vec<u8> {
    let mut wire = Vec::new();
    msg.write_to(&mut wire).unwrap();
    wire
}

// ---------------------------------------------------------------------------
// Properties
// ---------------------------------------------------------------------------

/// Exhaustive wire-kind pin: every `KIND_*` constant the protocol
/// declares is the discriminant some message actually encodes to.  This
/// is the runtime half of `pallas-lint`'s protocol-exhaustiveness rule —
/// the lint proves each constant has encode/decode arms, this proves the
/// arms produce the constant they claim.
#[test]
fn kind_constants_match_encoded_discriminants() {
    let mut g = Gen {
        rng: Xoshiro256pp::seed_from_u64(0x1D_C0DE),
        scale: 1.0,
        case: 0,
    };
    let expect = [
        KIND_HELLO,
        KIND_HELLO_ACK,
        KIND_JOB,
        KIND_ITER,
        KIND_DONE,
        KIND_ERROR,
        KIND_SHUTDOWN,
        KIND_PING,
        KIND_PONG,
        KIND_LOAD_SHARD,
        KIND_LOAD_ACK,
        KIND_CENTROIDS,
        KIND_PARTIALS,
        KIND_RELEASE,
        KIND_RELEASED,
        KIND_END_SESSION,
    ];
    assert_eq!(expect.len(), KINDS, "a kind was added without a pin");
    for (which, want) in expect.iter().enumerate() {
        let (kind, payload) = random_message(&mut g, which).encode();
        assert_eq!(kind, *want, "message index {which}");
        // And the decoder accepts its own discriminant.
        assert!(
            Message::decode(kind, &payload).is_ok(),
            "kind {kind} does not decode its own encoding"
        );
    }
}

#[test]
fn every_message_kind_round_trips_random_payloads() {
    // Miri runs the interpreter ~2 orders of magnitude slower; a thinner
    // sweep keeps the CI Miri job fast while native runs keep full depth.
    let cases = if cfg!(miri) { 6 } else { 48 };
    proptest_seeded(0xF1A9_E5, cases, |g| {
        for which in 0..KINDS {
            let msg = random_message(g, which);
            let wire = wire_of(&msg);
            let (back, rx) = Message::read_from(&mut Cursor::new(&wire))
                .map_err(|e| format!("kind {which}: read failed: {e}"))?;
            if rx != wire.len() {
                return Err(format!("kind {which}: rx {} != wire {}", rx, wire.len()));
            }
            // Message has no PartialEq; bitwise re-encode equality is the
            // stronger check anyway (exact IEEE bits, exact field order).
            if back.encode() != msg.encode() {
                return Err(format!("kind {which}: round trip not bitwise-identical"));
            }
        }
        Ok(())
    });
}

#[test]
fn any_single_byte_flip_is_refused_never_a_panic() {
    // One seeded message per kind, then flip every byte of its wire form
    // (two masks: low bit and high bit).  The frame layer must catch the
    // damage (magic, length bound, CRC) or, in the astronomically
    // unlikely event a frame survives, the message decoder must refuse.
    let mut g = Gen {
        rng: Xoshiro256pp::seed_from_u64(0xB17_F11),
        scale: 1.0,
        case: 0,
    };
    // Under Miri, sample every 17th byte (coprime to the frame layout so
    // header, payload and trailer bytes all get hit) instead of all of them.
    let stride = if cfg!(miri) { 17 } else { 1 };
    for which in 0..KINDS {
        let wire = wire_of(&random_message(&mut g, which));
        for i in (0..wire.len()).step_by(stride) {
            for mask in [0x01u8, 0x80u8] {
                let mut bad = wire.clone();
                bad[i] ^= mask;
                match Message::read_from(&mut Cursor::new(&bad)) {
                    Err(_) => {}
                    Ok(_) => panic!("kind {which}: flip {mask:#04x} at byte {i} was accepted"),
                }
            }
        }
    }
}

#[test]
fn truncation_at_every_boundary_reads_as_truncated() {
    let mut g = Gen {
        rng: Xoshiro256pp::seed_from_u64(0x7_2C47),
        scale: 1.0,
        case: 0,
    };
    let stride = if cfg!(miri) { 13 } else { 1 };
    for which in 0..KINDS {
        let wire = wire_of(&random_message(&mut g, which));
        for cut in (0..wire.len()).step_by(stride) {
            match Message::read_from(&mut Cursor::new(&wire[..cut])) {
                Err(FrameError::Truncated) => {}
                Err(e) => panic!("kind {which}: cut at {cut} gave {e}, want Truncated"),
                Ok(_) => panic!("kind {which}: cut at {cut} decoded a whole message"),
            }
        }
    }
}

#[test]
fn garbage_streams_are_rejected_without_panic() {
    let cases = if cfg!(miri) { 12 } else { 64 };
    proptest_seeded(0x6A2_BA6E, cases, |g| {
        let n = g.usize_in(0, 256);
        let blob: Vec<u8> = (0..n).map(|_| g.rng.next_u64() as u8).collect();
        // A random blob must never read as a protocol message (the magic
        // plus CRC make that a ~2^-64 accident) — and must never panic.
        if Message::read_from(&mut Cursor::new(&blob)).is_ok() {
            return Err(format!("{n}-byte garbage blob decoded as a message"));
        }
        Ok(())
    });
}
