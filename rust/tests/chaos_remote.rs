//! Chaos suite for the remote shard plane: a real `WorkerServer` behind
//! the deterministic fault-injecting [`ChaosProxy`], driven through the
//! coordinator's full degradation ladder (retry with backoff →
//! reschedule on another remote → local fallback).
//!
//! The invariant under *every* fault class: the final `KmeansResult` is
//! bitwise-identical to the in-process solve — the shard seed is a pure
//! function of `(base seed, shard index)`, so no recovery path can
//! change the answer — and a hung/stalled worker costs at most the
//! per-job deadline, never an unbounded stall.

use muchswift::coordinator::{Backend, CoordOutcome, Coordinator};
use muchswift::data::synthetic::generate_params;
use muchswift::data::Dataset;
use muchswift::kmeans::remote::{RemoteShardPool, RetryPolicy, WorkerServer};
use muchswift::kmeans::solver::KmeansSpec;
use muchswift::kmeans::KmeansResult;
use muchswift::util::fault::{ChaosProxy, FaultSchedule};
use std::io::{BufRead, BufReader};
use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

fn assert_bitwise_equal(a: &KmeansResult, b: &KmeansResult) {
    assert_eq!(a.centroids.len(), b.centroids.len());
    for (x, y) in a.centroids.flat().iter().zip(b.centroids.flat()) {
        assert_eq!(x.to_bits(), y.to_bits(), "centroid bits diverged");
    }
    assert_eq!(a.assignments, b.assignments, "assignments diverged");
}

/// Small timeouts so injected hangs/stalls cost milliseconds, tiny
/// backoff so retries are fast, but a roomy job deadline so the *attempt
/// count*, not wall-clock racing, decides the ladder — which is what
/// keeps the counter assertions deterministic.
fn fast_policy() -> RetryPolicy {
    RetryPolicy {
        max_attempts: 3,
        backoff_base: Duration::from_millis(2),
        backoff_max: Duration::from_millis(20),
        connect_timeout: Duration::from_secs(2),
        io_timeout: Duration::from_millis(400),
        job_deadline: Duration::from_secs(10),
        seed: 0xD00D,
    }
}

fn run_chaos(
    data: &Dataset,
    spec: &KmeansSpec,
    schedule: &str,
    policy: RetryPolicy,
) -> CoordOutcome {
    let w = WorkerServer::spawn("127.0.0.1:0").unwrap();
    let proxy = ChaosProxy::spawn(
        "127.0.0.1:0",
        &w.addr().to_string(),
        FaultSchedule::parse(schedule).unwrap(),
    )
    .unwrap();
    let out = Coordinator::new(Backend::Cpu)
        .with_remotes(RemoteShardPool::new(vec![proxy.addr().to_string()]).with_policy(policy))
        .run(data, spec);
    proxy.shutdown();
    w.shutdown().unwrap();
    out
}

#[test]
fn every_fault_class_preserves_bitwise_results() {
    let s = generate_params(1500, 2, 3, 0.2, 1.0, 21);
    // P = 1 with one endpoint: the single puller is the remote one, so
    // the fault schedule is hit deterministically, never raced away.
    let spec = KmeansSpec::two_level(3).seed(6).shards(1);
    let local = Coordinator::new(Backend::Cpu).run(&s.data, &spec);

    // Connection 0 carries the fault, connection 1 is clean: every class
    // must be detected, retried past, and end bitwise-identical.
    for fault in ["refuse", "hang", "truncate@3", "corrupt@3", "kill@3", "stall@3"] {
        let out = run_chaos(
            &s.data,
            &spec,
            &format!("{fault},none"),
            fast_policy(),
        );
        let m = &out.metrics;
        let disrupted =
            m.remote_retries + m.remote_timeouts + m.remote_fallbacks + m.remote_rescheduled;
        assert!(disrupted >= 1, "{fault}: no disruption recorded: {}", m.summary());
        assert_eq!(
            m.remote_shards + m.remote_fallbacks,
            1,
            "{fault}: the one shard must resolve exactly once: {}",
            m.summary()
        );
        assert_bitwise_equal(&out.result, &local.result);
    }

    // Delay is a *benign* fault: slower, but nothing to retry.
    let out = run_chaos(&s.data, &spec, "delay@25", fast_policy());
    assert_eq!(out.metrics.remote_shards, 1, "{}", out.metrics.summary());
    assert_eq!(out.metrics.remote_fallbacks, 0);
    assert_eq!(out.metrics.remote_retries, 0);
    assert_bitwise_equal(&out.result, &local.result);
}

#[test]
fn same_fault_schedule_twice_reproduces_counters_and_bits_exactly() {
    // One fault of every class in the schedule, the first three of which
    // (corrupt, kill, truncate) are actually consumed by the default
    // three attempts before the ladder ends in a local fallback — run
    // twice, the books and the bits must match exactly.
    let schedule = "corrupt@3,kill@3,truncate@3,stall@3,refuse,hang,delay@10,none";
    let s = generate_params(1500, 2, 3, 0.2, 1.0, 21);
    let spec = KmeansSpec::two_level(3).seed(6).shards(1);

    let a = run_chaos(&s.data, &spec, schedule, fast_policy());
    let b = run_chaos(&s.data, &spec, schedule, fast_policy());
    let books = |o: &CoordOutcome| {
        (
            o.metrics.remote_workers,
            o.metrics.remote_shards,
            o.metrics.remote_fallbacks,
            o.metrics.remote_retries,
            o.metrics.remote_timeouts,
            o.metrics.remote_reconnects,
            o.metrics.remote_rescheduled,
        )
    };
    assert_eq!(books(&a), books(&b), "chaos run not reproducible");
    assert_bitwise_equal(&a.result, &b.result);
    // This schedule exhausts all three attempts mid-solve (corrupt →
    // kill → truncate), so the ladder demonstrably ran before going
    // local.
    assert_eq!(books(&a), (1, 0, 1, 2, 0, 2, 0), "{}", a.metrics.summary());
    let local = Coordinator::new(Backend::Cpu).run(&s.data, &spec);
    assert_bitwise_equal(&a.result, &local.result);

    // Seed-derived schedules are themselves reproducible end to end.
    assert_eq!(
        FaultSchedule::seeded(0xC4A05, 8).to_string(),
        FaultSchedule::seeded(0xC4A05, 8).to_string()
    );
}

#[test]
fn stalled_worker_is_bounded_by_the_job_deadline() {
    // Every connection stalls mid-solve (handshake + pings pass, the
    // first Iter frame never comes).  The per-job deadline caps what
    // that costs: attempts stop the moment the budget is gone, and the
    // shard goes local.
    let s = generate_params(1500, 2, 3, 0.2, 1.0, 21);
    let spec = KmeansSpec::two_level(3).seed(6).shards(1);
    let local = Coordinator::new(Backend::Cpu).run(&s.data, &spec);

    let policy = RetryPolicy {
        io_timeout: Duration::from_millis(300),
        job_deadline: Duration::from_millis(700),
        max_attempts: 5,
        backoff_base: Duration::from_millis(1),
        ..fast_policy()
    };
    let t0 = Instant::now();
    let out = run_chaos(&s.data, &spec, "stall@3", policy);
    let elapsed = t0.elapsed();

    assert_eq!(out.metrics.remote_fallbacks, 1, "{}", out.metrics.summary());
    assert_eq!(out.metrics.remote_shards, 0);
    assert!(
        out.metrics.remote_timeouts >= 1,
        "stall must surface as timeouts: {}",
        out.metrics.summary()
    );
    // 700 ms of job budget + dials/backoff/local solve: nowhere near the
    // unbounded hang this test exists to prevent.  8s (not 5s) leaves
    // headroom for the TSan/ASan CI legs, whose instrumentation slows
    // wall-clock work several-fold without changing the bounded/unbounded
    // distinction this asserts.
    assert!(elapsed < Duration::from_secs(8), "took {elapsed:?}");
    assert_bitwise_equal(&out.result, &local.result);
}

#[test]
fn dead_remote_shard_is_rescheduled_onto_a_live_one() {
    // Endpoint A kills every connection mid-solve; endpoint B is a clean
    // worker.  A's shard must move to B (the ladder's middle rung): both
    // shards still solve remotely, nothing falls back to local.
    let s = generate_params(2400, 2, 3, 0.2, 1.0, 13);
    let spec = KmeansSpec::two_level(3).seed(4).shards(2).workers(2);
    let local = Coordinator::new(Backend::Cpu).run(&s.data, &spec);

    let wa = WorkerServer::spawn("127.0.0.1:0").unwrap();
    let wb = WorkerServer::spawn("127.0.0.1:0").unwrap();
    let proxy = ChaosProxy::spawn(
        "127.0.0.1:0",
        &wa.addr().to_string(),
        FaultSchedule::parse("kill@3").unwrap(),
    )
    .unwrap();
    let pool = RemoteShardPool::new(vec![
        proxy.addr().to_string(),
        wb.addr().to_string(),
    ])
    .with_policy(fast_policy());
    let out = Coordinator::new(Backend::Cpu)
        .with_remotes(pool)
        .run(&s.data, &spec);
    proxy.shutdown();
    wa.shutdown().unwrap();
    wb.shutdown().unwrap();

    let m = &out.metrics;
    assert_eq!(m.remote_workers, 2, "{}", m.summary());
    assert_eq!(m.remote_rescheduled, 1, "{}", m.summary());
    assert_eq!(m.remote_fallbacks, 0, "reschedule must beat local fallback");
    assert_eq!(m.remote_shards, 2, "both shards still solved remotely");
    assert!(m.remote_retries >= 2, "{}", m.summary());
    assert_bitwise_equal(&out.result, &local.result);
}

// ---------------------------------------------------------------------------
// Session plane under chaos (protocol v3)
// ---------------------------------------------------------------------------
//
// In session mode the server's frame sequence per connection is
// HelloAck (0), LoadAck (1), then one Partials per iteration — so a
// fault `@2` lands exactly on the *first Partials reduce*, the nastiest
// point: the shard is resident, an iteration is in flight, and the
// driver must re-run that step elsewhere without folding it twice.

#[test]
fn session_partials_kill_reloads_on_same_endpoint_bitwise() {
    let s = generate_params(2400, 2, 3, 0.2, 1.0, 13);
    let spec = KmeansSpec::two_level(3).seed(4).shards(2);
    let local = Coordinator::new(Backend::Cpu).run(&s.data, &spec);

    // Connection 0 (through the proxy) dies on its first Partials;
    // the reconnect gets the clean `none` slot, so rung 1 of the ladder
    // — revive the home endpoint, re-load, re-step — must succeed.
    let wa = WorkerServer::spawn("127.0.0.1:0").unwrap();
    let wb = WorkerServer::spawn("127.0.0.1:0").unwrap();
    let proxy = ChaosProxy::spawn(
        "127.0.0.1:0",
        &wa.addr().to_string(),
        FaultSchedule::parse("kill@2,none").unwrap(),
    )
    .unwrap();
    let pool = RemoteShardPool::new(vec![
        proxy.addr().to_string(),
        wb.addr().to_string(),
    ])
    .with_policy(fast_policy());
    let out = Coordinator::new(Backend::Cpu)
        .with_session(true)
        .with_remotes(pool)
        .run(&s.data, &spec);
    proxy.shutdown();
    wa.shutdown().unwrap();
    wb.shutdown().unwrap();

    let m = &out.metrics;
    assert_eq!(m.remote_workers, 2, "{}", m.summary());
    assert_eq!(m.shard_reloads, 1, "one recovery re-load: {}", m.summary());
    assert!(m.remote_reconnects >= 1, "{}", m.summary());
    assert_eq!(m.remote_fallbacks, 0, "reload must beat local fallback");
    assert_eq!(m.remote_shards, 2, "both shards finished resident remotely");
    assert_bitwise_equal(&out.result, &local.result);
}

#[test]
fn session_endpoint_that_keeps_dying_reloads_onto_the_live_one() {
    let s = generate_params(2400, 2, 3, 0.2, 1.0, 13);
    let spec = KmeansSpec::two_level(3).seed(4).shards(2);
    let local = Coordinator::new(Backend::Cpu).run(&s.data, &spec);

    // Every connection through the proxy dies on its first Partials
    // (a single-entry schedule applies to each new connection): rung 1
    // re-loads and dies again, so the shard must migrate to the clean
    // endpoint (rung 2) — two uploads beyond the first, zero fallbacks.
    let wa = WorkerServer::spawn("127.0.0.1:0").unwrap();
    let wb = WorkerServer::spawn("127.0.0.1:0").unwrap();
    let proxy = ChaosProxy::spawn(
        "127.0.0.1:0",
        &wa.addr().to_string(),
        FaultSchedule::parse("kill@2").unwrap(),
    )
    .unwrap();
    let pool = RemoteShardPool::new(vec![
        proxy.addr().to_string(),
        wb.addr().to_string(),
    ])
    .with_policy(fast_policy());
    let out = Coordinator::new(Backend::Cpu)
        .with_session(true)
        .with_remotes(pool)
        .run(&s.data, &spec);
    proxy.shutdown();
    wa.shutdown().unwrap();
    wb.shutdown().unwrap();

    let m = &out.metrics;
    assert_eq!(m.shard_reloads, 2, "retry on home, then migrate: {}", m.summary());
    assert_eq!(m.remote_fallbacks, 0, "{}", m.summary());
    assert_eq!(m.remote_shards, 2, "both shards ended resident on the live worker");
    assert_bitwise_equal(&out.result, &local.result);
}

#[test]
fn session_corrupted_partials_is_detected_and_recovered_bitwise() {
    let s = generate_params(1500, 2, 3, 0.2, 1.0, 21);
    let spec = KmeansSpec::two_level(3).seed(6).shards(1);
    let local = Coordinator::new(Backend::Cpu).run(&s.data, &spec);

    // The first Partials frame arrives bit-flipped: the frame CRC must
    // refuse it (never fold garbage sums), the connection is condemned,
    // and the clean reconnect re-runs the lost step.
    let w = WorkerServer::spawn("127.0.0.1:0").unwrap();
    let proxy = ChaosProxy::spawn(
        "127.0.0.1:0",
        &w.addr().to_string(),
        FaultSchedule::parse("corrupt@2,none").unwrap(),
    )
    .unwrap();
    let pool = RemoteShardPool::new(vec![proxy.addr().to_string()]).with_policy(fast_policy());
    let out = Coordinator::new(Backend::Cpu)
        .with_session(true)
        .with_remotes(pool)
        .run(&s.data, &spec);
    proxy.shutdown();
    w.shutdown().unwrap();

    let m = &out.metrics;
    assert_eq!(m.shard_reloads, 1, "{}", m.summary());
    assert_eq!(m.remote_fallbacks, 0, "{}", m.summary());
    assert_eq!(m.remote_shards, 1);
    assert_bitwise_equal(&out.result, &local.result);
}

#[test]
fn session_with_no_surviving_remote_falls_back_local_bitwise() {
    let s = generate_params(1500, 2, 3, 0.2, 1.0, 21);
    let spec = KmeansSpec::two_level(3).seed(6).shards(1);
    let local = Coordinator::new(Backend::Cpu).run(&s.data, &spec);

    // The only endpoint kills every connection on its first Partials:
    // rung 1 (reconnect + reload) dies the same way, there is no rung-2
    // peer, so the shard steps locally from there — results unaffected.
    let w = WorkerServer::spawn("127.0.0.1:0").unwrap();
    let proxy = ChaosProxy::spawn(
        "127.0.0.1:0",
        &w.addr().to_string(),
        FaultSchedule::parse("kill@2").unwrap(),
    )
    .unwrap();
    let pool = RemoteShardPool::new(vec![proxy.addr().to_string()]).with_policy(fast_policy());
    let out = Coordinator::new(Backend::Cpu)
        .with_session(true)
        .with_remotes(pool)
        .run(&s.data, &spec);
    proxy.shutdown();
    w.shutdown().unwrap();

    let m = &out.metrics;
    assert_eq!(m.remote_fallbacks, 1, "{}", m.summary());
    assert_eq!(m.shard_reloads, 1, "rung 1 was tried before going local");
    assert_eq!(m.remote_shards, 0, "the shard's final home was local");
    assert_bitwise_equal(&out.result, &local.result);
}

// ---------------------------------------------------------------------------
// chaos-proxy binary lifecycle
// ---------------------------------------------------------------------------

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_muchswift"))
}

#[test]
fn chaos_proxy_binary_fronts_a_worker() {
    use muchswift::kmeans::remote::RemoteWorker;

    let w = WorkerServer::spawn("127.0.0.1:0").unwrap();
    let mut child = bin()
        .args([
            "chaos-proxy",
            "--upstream",
            &w.addr().to_string(),
            "--schedule",
            "kill@1,none",
        ])
        .stdout(Stdio::piped())
        .spawn()
        .unwrap();
    // Scrape the bound address from the startup banner.
    let mut reader = BufReader::new(child.stdout.take().unwrap());
    let addr = loop {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "banner never came");
        if let Some(rest) = line.split("listening on ").nth(1) {
            break rest.split_whitespace().next().unwrap().to_string();
        }
    };
    // Connection 0 dies on its second frame (the connect Pong); the
    // default policy retries onto the clean connection 1.
    let rw = RemoteWorker::connect(&addr).unwrap();
    drop(rw);
    child.kill().unwrap();
    child.wait().unwrap();
    w.shutdown().unwrap();
}

#[test]
fn chaos_proxy_binary_rejects_bad_schedules() {
    let out = bin()
        .args([
            "chaos-proxy",
            "--upstream",
            "127.0.0.1:1",
            "--schedule",
            "explode@7",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
}
