//! Fit/predict acceptance suite:
//!
//! 1. Model round-trip — save → load → `Predictor::assign` produces
//!    bitwise-identical labels to the in-memory model, across both
//!    metrics and both CPU panel backends.
//! 2. Training-set parity — on a fully converged fit, the batched
//!    predictor reproduces `KmeansResult::assignments` exactly.
//! 3. CLI round trip — `gen-data` → `fit` → `predict` end to end, label
//!    files agree, and negative paths fail loudly.

use muchswift::data::synthetic::generate_params;
use muchswift::data::{csv, Dataset};
use muchswift::kmeans::model::KmeansModel;
use muchswift::kmeans::panel::{KernelKind, PanelKernel, ParCpuPanels};
use muchswift::kmeans::predict::Predictor;
use muchswift::kmeans::solver::{Algo, KmeansSpec, SolverCtx};
use muchswift::kmeans::Metric;
use std::path::PathBuf;
use std::process::Command;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("muchswift_mp_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn saved_model_predicts_bitwise_identically_to_in_memory() {
    let dir = temp_dir("roundtrip");
    for metric in [Metric::Euclid, Metric::Manhattan] {
        let s = generate_params(1500, 6, 7, 0.2, 2.0, 23);
        let spec = KmeansSpec::new(7).metric(metric).seed(4);
        let model = spec.fit(&mut SolverCtx::new(&s.data));
        let path = dir.join(format!("model_{}.json", metric.name()));
        model.save(&path).unwrap();
        let loaded = KmeansModel::load(&path).unwrap();
        // The artifact round-trips bitwise.
        assert_eq!(model.centroids, loaded.centroids, "{metric:?}");
        assert_eq!(model.metric, loaded.metric);
        assert_eq!(model.train, loaded.train);

        // Fresh query set (not the training data) through both CPU panel
        // backends: in-memory and loaded models must agree bit-for-bit.
        let q = generate_params(900, 6, 7, 0.5, 2.0, 77).data;
        for kernel in [PanelKernel::Scalar, PanelKernel::Blocked] {
            let a = Predictor::with_backend(&model, ParCpuPanels::with_kernel(3, kernel))
                .assign(&q);
            let b = Predictor::with_backend(&loaded, ParCpuPanels::with_kernel(3, kernel))
                .assign(&q);
            assert_eq!(a, b, "{metric:?} {kernel:?}");
        }
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn predictor_reproduces_training_assignments_exactly() {
    // tol = 0 runs Lloyd to an exact fixpoint: the final assignments are
    // the arg-min against the final centroids, so the scalar-kernel
    // predictor must reproduce them bit-for-bit.  Extreme separation +
    // k-means++ seeding make the fixpoint land within a few iterations
    // for both metrics (L1 + mean update has no descent guarantee in
    // general, but trivially stabilizes on planted well-separated data).
    for metric in [Metric::Euclid, Metric::Manhattan] {
        let s = generate_params(1200, 4, 5, 0.02, 10.0, 31);
        let spec = KmeansSpec::new(5)
            .metric(metric)
            .algo(Algo::Lloyd)
            .init(muchswift::kmeans::init::Init::KmeansPlusPlus)
            .tol(0.0)
            .max_iters(300)
            .seed(6);
        let mut ctx = SolverCtx::new(&s.data);
        let r = spec.solve(&mut ctx);
        assert!(r.stats.converged, "{metric:?}: fixpoint not reached");
        assert_eq!(r.stats.iters.last().unwrap().moved, 0.0);
        let model = KmeansModel::from_fit(&s.data, &r, &spec);
        let labels = Predictor::new(&model).assign(&s.data);
        assert_eq!(labels, r.assignments, "{metric:?}");
    }
}

#[test]
fn fit_convenience_equals_solve_plus_package() {
    let s = generate_params(800, 3, 4, 0.15, 2.0, 9);
    let spec = KmeansSpec::new(4).seed(12);
    let model = spec.fit(&mut SolverCtx::new(&s.data));
    let r = spec.solve(&mut SolverCtx::new(&s.data));
    // Deterministic spec ⇒ fit() packaged exactly the solve() outcome.
    assert_eq!(model.centroids, r.centroids);
    assert_eq!(model.train.iterations, r.stats.iterations());
    assert_eq!(model.train.converged, r.stats.converged);
}

#[test]
fn two_level_model_serves_predictions() {
    // The paper's own algorithm through the new surface: fit two-level,
    // persist, predict — labels must be valid and deterministic.
    let s = generate_params(3000, 3, 5, 0.1, 3.0, 41);
    let spec = KmeansSpec::two_level(5).seed(3);
    let model = spec.fit(&mut SolverCtx::new(&s.data));
    assert_eq!(model.spec.algo, Algo::TwoLevel);
    let dir = temp_dir("twolevel");
    let path = dir.join("model.json");
    model.save(&path).unwrap();
    let loaded = KmeansModel::load(&path).unwrap();
    let a = Predictor::new(&model).assign(&s.data);
    let b = Predictor::new(&loaded).assign(&s.data);
    assert_eq!(a, b);
    assert!(a.iter().all(|&l| (l as usize) < model.k()));
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------------
// CLI round trip
// ---------------------------------------------------------------------------

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_muchswift"))
}

#[test]
fn cli_fit_predict_round_trip() {
    let dir = temp_dir("cli");
    let data_csv = dir.join("data.csv");
    let model_json = dir.join("model.json");
    let fit_labels = dir.join("fit_labels.csv");
    let pred_labels = dir.join("pred_labels.csv");

    // gen-data → CSV.
    let out = bin()
        .args(["gen-data", "--n", "1500", "--d", "4", "--k", "5", "--seed", "3"])
        .arg(&data_csv)
        .output()
        .expect("spawn gen-data");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // fit with the l2 alias, writing model + training labels.
    let out = bin()
        .args(["fit", "--k", "5", "--metric", "l2", "--seed", "3", "--tol", "0"])
        .args(["--model", model_json.to_str().unwrap()])
        .args(["--out", fit_labels.to_str().unwrap()])
        .arg(&data_csv)
        .output()
        .expect("spawn fit");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "fit failed\nstdout: {stdout}\nstderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(stdout.contains("wrote model"), "{stdout}");
    assert!(model_json.exists());

    // The model file is a versioned kmeans-model JSON document.
    let model = KmeansModel::load(&model_json).unwrap();
    assert_eq!(model.k(), 5);
    assert_eq!(model.dims(), 4);

    // predict against the same dataset.
    let out = bin()
        .args(["predict", "--model", model_json.to_str().unwrap()])
        .args(["--out", pred_labels.to_str().unwrap()])
        .arg(&data_csv)
        .output()
        .expect("spawn predict");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "predict failed\nstdout: {stdout}\nstderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(stdout.contains("objective"), "{stdout}");

    // Both label files exist and agree exactly: fit's training labels are
    // produced by the same predictor serving uses.
    let a = csv::load_labels(&fit_labels).unwrap();
    let b = csv::load_labels(&pred_labels).unwrap();
    assert_eq!(a.len(), 1500);
    assert_eq!(a, b);

    // And they match an in-process predict over the same artifacts.
    let data = csv::load(&data_csv).unwrap();
    let want = Predictor::new(&model).assign(&data);
    assert_eq!(a, want);

    for f in [&data_csv, &model_json, &fit_labels, &pred_labels] {
        std::fs::remove_file(f).ok();
    }
}

#[test]
fn cli_cluster_out_writes_assignments() {
    let dir = temp_dir("cluster_out");
    let labels_csv = dir.join("labels.csv");
    let out = bin()
        .args([
            "cluster", "--backend", "cpu", "--algo", "lloyd", "--n", "800", "--d", "3",
            "--k", "4", "--seed", "5",
        ])
        .args(["--out", labels_csv.to_str().unwrap()])
        .output()
        .expect("spawn cluster");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let labels = csv::load_labels(&labels_csv).unwrap();
    assert_eq!(labels.len(), 800);
    assert!(labels.iter().all(|&l| l < 4));
    std::fs::remove_file(&labels_csv).ok();
}

#[test]
fn cli_rejects_bad_metric_kernel_and_missing_model() {
    // Unknown metric on fit (the satellite's negative path).
    let out = bin()
        .args(["fit", "--metric", "cosine"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown metric"), "{stderr}");

    // Unknown kernel on predict.
    let dir = temp_dir("neg");
    let data_csv = dir.join("d.csv");
    csv::save(&Dataset::from_flat(2, 2, vec![0.0, 0.0, 1.0, 1.0]), &data_csv).unwrap();
    let out = bin()
        .args(["predict", "--model", "nope.json", "--kernel", "warp"])
        .arg(&data_csv)
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown kernel"), "{stderr}");

    // Missing model file is a clean error, not a panic.
    let out = bin()
        .args(["predict", "--model", "/nonexistent/model.json"])
        .arg(&data_csv)
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("cannot read model"), "{stderr}");

    // predict without an input dataset.
    let out = bin()
        .args(["predict", "--model", "/nonexistent/model.json"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    std::fs::remove_file(&data_csv).ok();
}

// ---------------------------------------------------------------------------
// Quantized shortlist parity (ISSUE 9 satellite)
// ---------------------------------------------------------------------------

#[test]
fn quantized_predictor_is_bitwise_identical_to_scalar_oracle() {
    // The i8 shortlist may only *narrow* the candidate set — survivors are
    // re-scored in exact f32 — so labels AND assigned distances must match
    // the scalar oracle bit-for-bit on both metrics, including queries far
    // from the training distribution.
    for metric in [Metric::Euclid, Metric::Manhattan] {
        let s = generate_params(2000, 8, 9, 0.3, 2.0, 51);
        let spec = KmeansSpec::new(9).metric(metric).seed(7);
        let model = spec.fit(&mut SolverCtx::new(&s.data));
        let q = generate_params(1200, 8, 9, 0.6, 2.0, 99).data;
        let (want_l, want_d) = Predictor::new(&model).assign_scored(&q);
        let (got_l, got_d) = Predictor::quantized(&model).assign_scored(&q);
        assert_eq!(got_l, want_l, "{metric:?}: labels drifted");
        assert_eq!(got_d, want_d, "{metric:?}: distances drifted");
    }
}

#[test]
fn quantized_predictor_keeps_lowest_index_tie_rule() {
    // Duplicated centroids force exact distance ties; the shortlist must
    // keep every tied candidate alive so the exact re-score can apply the
    // same lowest-index rule as the scalar oracle.
    for metric in [Metric::Euclid, Metric::Manhattan] {
        let s = generate_params(600, 2, 4, 0.2, 2.0, 5);
        let spec = KmeansSpec::new(4).metric(metric).seed(1);
        let mut model = spec.fit(&mut SolverCtx::new(&s.data));
        model.centroids =
            Dataset::from_flat(4, 2, vec![1.0, 1.0, 5.0, 5.0, 1.0, 1.0, 5.0, 5.0]);
        // On-centroid queries (ties between the duplicate pair), the exact
        // midpoint (a four-way tie under both metrics), and off-grid ones.
        let q = Dataset::from_flat(
            5,
            2,
            vec![1.0, 1.0, 5.0, 5.0, 3.0, 3.0, 0.9, 1.2, 4.8, 5.1],
        );
        let (want_l, want_d) = Predictor::new(&model).assign_scored(&q);
        let (got_l, got_d) = Predictor::quantized(&model).assign_scored(&q);
        assert_eq!(got_l, want_l, "{metric:?}");
        assert_eq!(got_d, want_d, "{metric:?}");
        assert_eq!(got_l[0], 0, "{metric:?}: duplicate tie must pick index 0");
        assert_eq!(got_l[1], 1, "{metric:?}: duplicate tie must pick index 1");
        assert_eq!(got_l[2], 0, "{metric:?}: four-way midpoint tie picks index 0");
    }
}

#[test]
fn kd_prune_auto_threshold_is_pinned_at_k_32() {
    // The Predictor's kd-tree-over-centroids prune auto-enables at
    // k >= 32 (`PRUNE_MIN_K`, DESIGN.md §3): below that, the shortlist
    // build costs more than the brute scan it saves.  Pin the boundary
    // so the constant can't silently drift, and that an explicit
    // `prune(on)` overrides the heuristic in both directions.
    for (k, auto_on) in [(31usize, false), (32, true), (33, true)] {
        let s = generate_params(k * 20, 4, k, 0.1, 2.0, 60 + k as u64);
        let spec = KmeansSpec::new(k).seed(8).max_iters(5);
        let model = spec.fit(&mut SolverCtx::new(&s.data));
        assert_eq!(
            Predictor::new(&model).pruning(),
            auto_on,
            "auto prune at k={k}"
        );
        assert!(Predictor::new(&model).prune(true).pruning(), "k={k}");
        assert!(!Predictor::new(&model).prune(false).pruning(), "k={k}");
        // The heuristic only picks a default; labels never depend on it.
        let q = generate_params(400, 4, k, 0.4, 2.0, 90 + k as u64).data;
        let a = Predictor::new(&model).prune(false).assign(&q);
        let b = Predictor::new(&model).prune(true).assign(&q);
        assert_eq!(a, b, "k={k}: prune changed labels");
    }
}

#[test]
fn simd_kernel_predictor_labels_match_scalar_oracle() {
    // Label-level parity for the SIMD tier (panel values are pinned to
    // 1e-4 in tests/panel_engine.rs; labels must agree exactly wherever
    // distances aren't within float noise of a tie, which planted
    // well-separated clusters guarantee).
    for metric in [Metric::Euclid, Metric::Manhattan] {
        let s = generate_params(1500, 16, 6, 0.05, 6.0, 17);
        let spec = KmeansSpec::new(6).metric(metric).seed(2);
        let model = spec.fit(&mut SolverCtx::new(&s.data));
        let q = generate_params(800, 16, 6, 0.05, 6.0, 18).data;
        let want = Predictor::new(&model).assign(&q);
        let got = Predictor::with_kernel_kind(&model, 3, KernelKind::Auto).assign(&q);
        assert_eq!(got, want, "{metric:?}");
    }
}
