//! Scratch: inspect breakdowns (not part of the example set).
use muchswift::arch::{evaluate, measure, ArchKind};
use muchswift::config::WorkloadConfig;

fn main() {
    let w = WorkloadConfig { n: 1_000_000, d: 15, k: 20, true_k: 20, sigma: 0.15, seed: 42, max_iters: 60, ..Default::default() };
    for kind in [ArchKind::FpgaFilterSingle, ArchKind::MuchSwift] {
        let m = measure(kind, &w);
        let it = &m.stats.iters[1];
        println!("{}: iters={} dist_evals/iter={} node_visits={} leaf_points={} interior={} prune={} levels={}",
            kind.name(), m.stats.iterations(), it.dist_evals, it.node_visits, it.leaf_points,
            it.interior_assigns, it.prune_tests, it.levels.len());
        println!("  run totals: dist_evals={} node_visits={} prune_tests={} leaf_points={} interior_assigns={}",
            m.stats.total_dist_evals(), m.stats.total_node_visits(), m.stats.total_prune_tests(),
            m.stats.total_leaf_points(), m.stats.total_interior_assigns());
        for (i, l) in it.levels.iter().enumerate() {
            if l.interior_jobs + l.leaf_jobs > 0 {
                println!("  lvl {i}: interior={} leaf={} cand={} prune={}", l.interior_jobs, l.leaf_jobs, l.cand_evals, l.prune_tests);
            }
        }
        let r = evaluate(kind, &w);
        println!("  total={:.3}s ingest={:.3}s pl={:.3}s ps={:.3}s xfer={:.3}s stall={:.3}s iters={}",
            r.total_s, r.ingest_s, r.breakdown.pl_s, r.breakdown.ps_s, r.breakdown.xfer_s, r.breakdown.stall_s, r.iterations);
    }
}
