//! Domain example: FPGA capacity planning for a cloud "Sensing as a
//! Service" deployment (the paper's section-1 scenario: dynamic,
//! priori-unknown clustering workloads on reconfigurable data-center
//! accelerators).
//!
//! Given a mix of tenant workloads, use the Table-1 resource model to pick
//! the largest fully-parallel cluster configuration per tenant, then use
//! the platform model to quote expected latency per request and compare
//! deployment options (software pool vs MUCH-SWIFT boards).
//!
//!     cargo run --release --example capacity_planner

use muchswift::arch::{evaluate, ArchKind};
use muchswift::config::WorkloadConfig;
use muchswift::hw::resources;

struct Tenant {
    name: &'static str,
    n: usize,
    d: usize,
    k: usize,
    requests_per_hour: f64,
}

fn main() {
    let tenants = [
        Tenant { name: "iot-telemetry", n: 400_000, d: 8, k: 12, requests_per_hour: 60.0 },
        Tenant { name: "geo-imagery", n: 1_000_000, d: 15, k: 20, requests_per_hour: 12.0 },
        Tenant { name: "fraud-features", n: 250_000, d: 30, k: 6, requests_per_hour: 120.0 },
        Tenant { name: "genomics-micro", n: 100_000, d: 15, k: 64, requests_per_hour: 4.0 },
    ];

    println!("ZU9EG capacity plan (Table-1 resource model):\n");
    let mut board_busy = 0f64; // seconds of board time per hour
    let mut sw_busy = 0f64;
    for t in &tenants {
        let fits = resources::fits(t.k);
        let kp = if fits {
            t.k
        } else {
            resources::max_parallel_clusters()
        };
        let u = resources::utilization(kp.min(20));
        let w = WorkloadConfig {
            n: t.n,
            d: t.d,
            k: t.k,
            true_k: t.k,
            sigma: 0.15,
            seed: 7,
            max_iters: 60,
            ..Default::default()
        };
        let ms = evaluate(ArchKind::MuchSwift, &w);
        let sw = evaluate(ArchKind::SwLloyd, &w);
        board_busy += ms.total_s * t.requests_per_hour;
        sw_busy += sw.total_s * t.requests_per_hour;
        println!(
            "  {:<16} k={:<3} {} | LUT {:>6.1}% DSP {:>6.1}% BRAM {:>6.1}% | \
             latency {:>8.3}s (sw {:>8.2}s, {:>5.0}x)",
            t.name,
            t.k,
            if fits { "fully-parallel" } else { "module-shared " },
            100.0 * u.luts as f64 / resources::ZU9EG.luts as f64,
            100.0 * u.dsps as f64 / resources::ZU9EG.dsps as f64,
            100.0 * u.brams as f64 / resources::ZU9EG.brams as f64,
            ms.total_s,
            sw.total_s,
            sw.total_s / ms.total_s,
        );
    }
    println!("\nfleet sizing at the given request rates:");
    println!(
        "  MUCH-SWIFT boards needed: {:.2} (busy {:.0} s/h each)",
        board_busy / 3600.0,
        3600.0
    );
    println!(
        "  software-only cores needed: {:.1}",
        sw_busy / 3600.0
    );
    println!(
        "  consolidation ratio: {:.0}x",
        sw_busy / board_busy.max(1e-9)
    );
}
