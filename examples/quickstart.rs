//! Quickstart: cluster a small synthetic dataset through the full
//! MUCH-SWIFT stack (coordinator -> 4 workers -> PL offload via the
//! AOT-compiled Pallas kernels on PJRT).
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Falls back to the CPU panel backend if artifacts are missing.

use muchswift::coordinator::{Backend, Coordinator, CoordinatorOpts};
use muchswift::data::synthetic::generate_params;
use muchswift::kmeans::Metric;
use muchswift::runtime::{self, PjrtRuntime};
use std::sync::Arc;

fn main() {
    muchswift::util::logger::init();

    // 20k points in 8 dimensions around 5 planted centers.
    let n = 20_000;
    let (d, k) = (8, 5);
    let s = generate_params(n, d, k, 0.1, 2.0, 7);
    println!("dataset: {n} points x {d} dims, {k} planted clusters");

    let backend = match PjrtRuntime::load(&runtime::default_artifact_dir()) {
        Ok(rt) => {
            println!("backend: pjrt ({} artifacts loaded)", rt.manifest().entries.len());
            Backend::Pjrt(Arc::new(rt))
        }
        Err(e) => {
            println!("backend: cpu (pjrt unavailable: {e})");
            Backend::Cpu
        }
    };

    let coord = Coordinator::new(backend);
    let out = coord.run(
        &s.data,
        &CoordinatorOpts {
            k,
            metric: Metric::Euclid,
            seed: 1,
            // k-means++ seeding per quarter: uniform sampling often lands
            // in local optima with empty merged clusters at small k.
            init: muchswift::kmeans::init::Init::KmeansPlusPlus,
            ..Default::default()
        },
    );

    println!("converged: {}", out.result.stats.converged);
    println!("cluster sizes: {:?}", out.result.sizes());
    println!("objective: {:.4e}", out.result.objective(&s.data, Metric::Euclid));

    // How close did we land to the planted centers?
    let mut worst = 0f32;
    for t in s.true_centroids.iter() {
        let best = out
            .result
            .centroids
            .iter()
            .map(|c| Metric::Euclid.dist(c, t))
            .fold(f32::INFINITY, f32::min);
        worst = worst.max(best);
    }
    println!("worst planted-center recovery distance^2: {worst:.4}");
    println!("{}", out.metrics.summary());
}
