//! Quickstart: cluster a small synthetic dataset through the unified
//! solver API, then through the full MUCH-SWIFT stack (coordinator ->
//! 4 workers -> PL offload via the AOT-compiled Pallas kernels on PJRT).
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Falls back to the CPU panel backend if artifacts are missing.  Every
//! step *asserts* its outcome (convergence, objective parity, planted-
//! center recovery), so building and running this example doubles as an
//! API-stability check.

use muchswift::coordinator::{Backend, Coordinator};
use muchswift::data::synthetic::generate_params;
use muchswift::kmeans::init::Init;
use muchswift::kmeans::model::KmeansModel;
use muchswift::kmeans::predict::Predictor;
use muchswift::kmeans::solver::{Algo, IterEvent, IterFlow, KmeansSpec, SolverCtx};
use muchswift::kmeans::Metric;
use muchswift::runtime::{self, PjrtRuntime};
use muchswift::serve::{ClusterService, ServeConfig};
use std::sync::Arc;

fn main() {
    muchswift::util::logger::init();

    // 20k points in 8 dimensions around 5 planted centers.
    let n = 20_000;
    let (d, k) = (8, 5);
    let s = generate_params(n, d, k, 0.1, 2.0, 7);
    println!("dataset: {n} points x {d} dims, {k} planted clusters");

    // One spec drives every algorithm.  k-means++ seeding: uniform
    // sampling often lands in local optima with empty merged clusters at
    // small k.
    let spec = KmeansSpec::two_level(k)
        .metric(Metric::Euclid)
        .init(Init::KmeansPlusPlus)
        .seed(1);

    // ---- Unified solver API (single process), with a live observer ------
    let iters = std::cell::Cell::new(0usize);
    let out = spec.solve(&mut SolverCtx::new(&s.data).observe(|_ev: &IterEvent| {
        iters.set(iters.get() + 1);
        IterFlow::Continue
    }));
    assert!(out.stats.converged, "two-level solver did not converge");
    assert!(iters.get() > 0, "observer saw no iterations");
    let obj_twolevel = out.objective(&s.data, Metric::Euclid);
    println!(
        "solver API: converged in {} observed iterations, objective {obj_twolevel:.4e}",
        iters.get()
    );

    // Lloyd through the same API as the quality baseline.
    let baseline = spec.clone().algo(Algo::Lloyd).solve(&mut SolverCtx::new(&s.data));
    let obj_lloyd = baseline.objective(&s.data, Metric::Euclid);
    assert!(
        obj_twolevel <= obj_lloyd * 1.25,
        "two-level objective {obj_twolevel:.4e} regressed vs lloyd {obj_lloyd:.4e}"
    );

    // ---- Fit/predict split: model artifact + batched inference ----------
    let model = spec.fit(&mut SolverCtx::new(&s.data));
    let model_path = std::env::temp_dir().join("muchswift_quickstart_model.json");
    model.save(&model_path).expect("model save");
    let loaded = KmeansModel::load(&model_path).expect("model load");
    assert_eq!(model.centroids, loaded.centroids, "round trip must be bitwise");
    let fresh = generate_params(2_000, d, k, 0.1, 2.0, 99).data;
    let labels_mem = Predictor::new(&model).assign(&fresh);
    let labels_disk = Predictor::new(&loaded).assign(&fresh);
    assert_eq!(labels_mem, labels_disk, "loaded model must predict identically");
    println!(
        "fit/predict: model round-tripped through {}, {} fresh points assigned",
        model_path.display(),
        fresh.len()
    );
    std::fs::remove_file(&model_path).ok();

    // ---- Micro-batching service over the model ---------------------------
    let svc = ClusterService::start(Arc::new(loaded), ServeConfig::default());
    let reply = svc.predict(fresh.clone()).expect("serve predict");
    assert_eq!(reply.labels.len(), fresh.len());
    let serve_metrics = svc.shutdown();
    assert_eq!(serve_metrics.requests, 1);
    println!("{}", serve_metrics.summary());

    // ---- The deployable system (threads + offload service) --------------
    let backend = match PjrtRuntime::load(&runtime::default_artifact_dir()) {
        Ok(rt) => {
            println!("backend: pjrt ({} artifacts loaded)", rt.manifest().entries.len());
            Backend::Pjrt(Arc::new(rt))
        }
        Err(e) => {
            println!("backend: cpu (pjrt unavailable: {e})");
            Backend::Cpu
        }
    };
    let coord = Coordinator::new(backend);
    let sys = coord.run(&s.data, &spec);
    assert!(sys.result.stats.converged, "coordinator did not converge");
    assert_eq!(sys.result.assignments.len(), n);
    assert_eq!(sys.result.sizes().iter().sum::<usize>(), n);
    println!("system: converged, cluster sizes {:?}", sys.result.sizes());
    println!("objective: {:.4e}", sys.result.objective(&s.data, Metric::Euclid));

    // How close did we land to the planted centers?
    let mut worst = 0f32;
    for t in s.true_centroids.iter() {
        let best = sys
            .result
            .centroids
            .iter()
            .map(|c| Metric::Euclid.dist(c, t))
            .fold(f32::INFINITY, f32::min);
        worst = worst.max(best);
    }
    assert!(
        worst < 1.0,
        "planted-center recovery too loose: worst distance^2 {worst}"
    );
    println!("worst planted-center recovery distance^2: {worst:.4}");
    println!("{}", sys.metrics.summary());
    println!("quickstart OK");
}
