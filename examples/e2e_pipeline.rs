//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Exercises every layer of the reproduction on one real workload:
//!
//! 1. generate a paper-style dataset (normal clusters, uniform centers);
//! 2. cluster it with the deployable system — Rust coordinator, 4 worker
//!    threads, PL offload through the AOT Pallas/XLA artifacts (PJRT) —
//!    and verify the clustering against the planted truth AND against a
//!    pure-software Lloyd run;
//! 3. feed the measured per-iteration work counters into the ZCU102
//!    platform model and report the paper's headline metric: simulated
//!    MUCH-SWIFT speedup over the software-only solution (~330x in the
//!    paper), plus the Fig. 2/3 baseline ratios at this workload.
//!
//!     make artifacts && cargo run --release --example e2e_pipeline

use muchswift::arch::{evaluate, ArchKind};
use muchswift::config::WorkloadConfig;
use muchswift::coordinator::{Backend, Coordinator};
use muchswift::data::synthetic;
use muchswift::kmeans::init::Init;
use muchswift::kmeans::solver::{Algo, KmeansSpec, SolverCtx};
use muchswift::kmeans::Metric;
use muchswift::runtime::{self, PjrtRuntime};
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    muchswift::util::logger::init();
    println!("=== MUCH-SWIFT end-to-end pipeline ===\n");

    // ---- 1. workload ------------------------------------------------------
    let w = WorkloadConfig {
        n: 60_000,
        d: 15,
        k: 12,
        true_k: 12,
        sigma: 0.12,
        seed: 2024,
        max_iters: 60,
        ..Default::default()
    };
    println!(
        "[1/3] dataset: {} points x {} dims, k={} ({} MB)",
        w.n,
        w.d,
        w.k,
        w.dataset_bytes() / (1 << 20)
    );
    let s = synthetic::generate(&w);

    // ---- 2. the real system ------------------------------------------------
    let rt = PjrtRuntime::load(&runtime::default_artifact_dir())?;
    println!(
        "[2/3] clustering through coordinator + PJRT ({} artifacts)",
        rt.manifest().entries.len()
    );
    let coord = Coordinator::new(Backend::Pjrt(Arc::new(rt)));
    let t0 = Instant::now();
    let out = coord.run(
        &s.data,
        &KmeansSpec::two_level(w.k).metric(w.metric).seed(w.seed),
    );
    let host_wall = t0.elapsed().as_secs_f64();
    println!("      {}", out.metrics.summary());

    // Truth check: every planted center recovered.
    let mut recovered = 0;
    for t in s.true_centroids.iter() {
        let best = out
            .result
            .centroids
            .iter()
            .map(|c| Metric::Euclid.dist(c, t))
            .fold(f32::INFINITY, f32::min);
        if best < (4.0 * w.sigma * w.sigma) * w.d as f32 {
            recovered += 1;
        }
    }
    println!("      planted centers recovered: {recovered}/{}", w.true_k);

    // Quality check vs an independent software Lloyd run (same unified
    // solver API, different strategy).
    let sw = KmeansSpec::new(w.k)
        .algo(Algo::Lloyd)
        .metric(w.metric)
        .init(Init::KmeansPlusPlus)
        .seed(5)
        .solve(&mut SolverCtx::new(&s.data));
    let obj_system = out.result.objective(&s.data, w.metric);
    let obj_sw = sw.objective(&s.data, w.metric);
    println!(
        "      objective: system {obj_system:.4e} vs software lloyd {obj_sw:.4e} (ratio {:.3})",
        obj_system / obj_sw
    );
    anyhow::ensure!(
        obj_system <= obj_sw * 1.25,
        "system clustering quality regressed vs software baseline"
    );

    // ---- 3. paper headline on the platform model ---------------------------
    println!("\n[3/3] ZCU102 platform model (simulated):");
    let mut rows = Vec::new();
    for kind in [
        ArchKind::SwLloyd,
        ArchKind::FpgaLloydSingle,
        ArchKind::FpgaFilterSingle,
        ArchKind::FpgaLloydMulti,
        ArchKind::MuchSwift,
    ] {
        let r = evaluate(kind, &w);
        println!("      {}", r.row());
        rows.push((kind, r.total_s));
    }
    let total = |k: ArchKind| rows.iter().find(|(a, _)| *a == k).unwrap().1;
    let ms = total(ArchKind::MuchSwift);
    println!("\n      headline: {:.0}x vs software-only (paper ~330x at 10^6 points)", total(ArchKind::SwLloyd) / ms);
    println!("      vs conventional FPGA: {:.0}x (paper: >210x avg)", total(ArchKind::FpgaLloydSingle) / ms);
    println!("      vs [13]: {:.1}x   vs [17]: {:.1}x (paper: ~8.5x / ~12x)",
        total(ArchKind::FpgaFilterSingle) / ms, total(ArchKind::FpgaLloydMulti) / ms);
    println!("\nhost wall-clock for the real run: {host_wall:.2} s");
    println!("e2e pipeline OK");
    Ok(())
}
