//! Domain example: unsupervised multispectral image segmentation — the
//! application the paper's introduction motivates (Theiler & Gisler [2]:
//! clustering pixel spectra to segment satellite imagery).
//!
//! We synthesize a W x H "scene" of 6-band pixel spectra from a handful of
//! ground-truth materials (with per-material spectral signatures, spatial
//! structure and sensor noise), segment it with the MUCH-SWIFT coordinator,
//! and score the segmentation against the ground truth.
//!
//!     cargo run --release --example multispectral_segmentation

use muchswift::coordinator::{Backend, Coordinator};
use muchswift::kmeans::solver::KmeansSpec;
use muchswift::data::Dataset;
use muchswift::kmeans::Metric;
use muchswift::runtime::{self, PjrtRuntime};
use muchswift::util::rng::Xoshiro256pp;
use std::sync::Arc;

const W: usize = 256;
const H: usize = 256;
const BANDS: usize = 6;
const MATERIALS: usize = 5;

/// Synthesize the scene: smooth material regions (Voronoi of random
/// sites) + per-material spectral signature + Gaussian sensor noise.
fn synthesize(seed: u64) -> (Dataset, Vec<u8>) {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    // Material spectral signatures in [0, 1]^BANDS.
    let sigs: Vec<Vec<f32>> = (0..MATERIALS)
        .map(|_| (0..BANDS).map(|_| rng.uniform_f32(0.1, 0.9)).collect())
        .collect();
    // Spatial structure: 24 Voronoi sites, each assigned a material.
    let sites: Vec<(f32, f32, u8)> = (0..24)
        .map(|_| {
            (
                rng.uniform_f32(0.0, W as f32),
                rng.uniform_f32(0.0, H as f32),
                rng.below_usize(MATERIALS) as u8,
            )
        })
        .collect();

    let mut flat = Vec::with_capacity(W * H * BANDS);
    let mut truth = Vec::with_capacity(W * H);
    for y in 0..H {
        for x in 0..W {
            let mut best = (f32::INFINITY, 0u8);
            for &(sx, sy, m) in &sites {
                let d = (x as f32 - sx).powi(2) + (y as f32 - sy).powi(2);
                if d < best.0 {
                    best = (d, m);
                }
            }
            let m = best.1;
            truth.push(m);
            for b in 0..BANDS {
                flat.push((sigs[m as usize][b] + rng.normal(0.0, 0.02)).clamp(0.0, 1.0));
            }
        }
    }
    (Dataset::from_flat(W * H, BANDS, flat), truth)
}

/// Segmentation accuracy under the best greedy cluster->material mapping.
fn score(assignments: &[u32], truth: &[u8], k: usize) -> f64 {
    // confusion[cluster][material]
    let mut confusion = vec![[0u32; MATERIALS]; k];
    for (a, &t) in assignments.iter().zip(truth.iter()) {
        confusion[*a as usize][t as usize] += 1;
    }
    let correct: u32 = confusion
        .iter()
        .map(|row| *row.iter().max().unwrap())
        .sum();
    correct as f64 / truth.len() as f64
}

fn main() -> anyhow::Result<()> {
    muchswift::util::logger::init();
    println!("multispectral scene: {W}x{H} pixels, {BANDS} bands, {MATERIALS} materials");
    let (pixels, truth) = synthesize(31);

    let backend = match PjrtRuntime::load(&runtime::default_artifact_dir()) {
        Ok(rt) => Backend::Pjrt(Arc::new(rt)),
        Err(_) => Backend::Cpu,
    };
    let coord = Coordinator::new(backend);
    let out = coord.run(
        &pixels,
        &KmeansSpec::two_level(MATERIALS)
            .metric(Metric::Euclid)
            .init(muchswift::kmeans::init::Init::KmeansPlusPlus)
            .seed(9),
    );

    let acc = score(&out.result.assignments, &truth, MATERIALS);
    println!("segmentation accuracy: {:.2}%", acc * 100.0);
    println!("cluster sizes: {:?}", out.result.sizes());
    println!("{}", out.metrics.summary());
    anyhow::ensure!(acc > 0.90, "segmentation accuracy {acc:.3} below 90%");
    println!("segmentation OK");
    Ok(())
}
