"""Pallas assignment kernel vs the pure-jnp oracle (ref.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import assign as ak
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(rng, *shape, scale=10.0):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


@pytest.mark.parametrize("metric", ref.METRICS)
@pytest.mark.parametrize("n,d,k,bn", [(64, 3, 5, 16), (128, 16, 32, 64), (256, 1, 2, 256)])
def test_assign_matches_ref(metric, n, d, k, bn):
    rng = np.random.default_rng(7)
    x = rand(rng, n, d)
    c = rand(rng, k, d)
    idx, mind = ak.assign(x, c, metric=metric, block_n=bn)
    ridx, rmind = ref.assign(x, c, metric=metric)
    assert_allclose(np.asarray(mind), np.asarray(rmind), rtol=2e-5, atol=1e-4)
    # arg-min may legitimately differ on exact ties; check distances agree
    d_at = np.take_along_axis(
        np.asarray(ref.pair_dists(x, c, metric)), np.asarray(idx)[:, None], axis=1
    )[:, 0]
    assert_allclose(d_at, np.asarray(rmind), rtol=2e-5, atol=1e-4)


@pytest.mark.parametrize("metric", ref.METRICS)
def test_assign_padded_centroids_never_win(metric):
    rng = np.random.default_rng(3)
    x = rand(rng, 64, 8)
    c = rand(rng, 5, 8)
    cpad = jnp.concatenate([c, jnp.full((3, 8), ref.PAD_SENTINEL, jnp.float32)])
    idx, _ = ak.assign(x, cpad, metric=metric, block_n=32)
    assert int(jnp.max(idx)) < 5
    ridx, _ = ref.assign(x, c, metric=metric)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))


def test_assign_rejects_ragged_block():
    x = jnp.zeros((100, 4), jnp.float32)
    c = jnp.zeros((4, 4), jnp.float32)
    with pytest.raises(ValueError, match="multiple"):
        ak.assign(x, c, block_n=64)


def test_assign_single_centroid():
    rng = np.random.default_rng(11)
    x = rand(rng, 32, 4)
    c = rand(rng, 1, 4)
    idx, mind = ak.assign(x, c, block_n=32)
    assert np.all(np.asarray(idx) == 0)
    assert_allclose(np.asarray(mind), np.asarray(ref.pair_dists(x, c))[:, 0], rtol=2e-5)


def test_euclid_is_squared_distance():
    x = jnp.asarray([[0.0, 0.0], [3.0, 4.0]], jnp.float32)
    c = jnp.asarray([[0.0, 0.0]], jnp.float32)
    _, mind = ak.assign(x, c, block_n=2)
    assert_allclose(np.asarray(mind), [0.0, 25.0], atol=1e-5)


def test_manhattan_matches_hand_value():
    x = jnp.asarray([[1.0, -2.0, 0.5]], jnp.float32)
    c = jnp.asarray([[0.0, 0.0, 0.0], [1.0, -2.0, 0.5]], jnp.float32)
    idx, mind = ak.assign(x, c, metric="manhattan", block_n=1)
    assert int(idx[0]) == 1
    assert_allclose(float(mind[0]), 0.0, atol=1e-6)
    d = ref.pair_dists(x, c, "manhattan")
    assert_allclose(float(d[0, 0]), 3.5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    n_blocks=st.integers(1, 4),
    bn=st.sampled_from([8, 16, 32]),
    d=st.integers(1, 24),
    k=st.integers(1, 33),
    metric=st.sampled_from(ref.METRICS),
    seed=st.integers(0, 2**31 - 1),
)
def test_assign_hypothesis_sweep(n_blocks, bn, d, k, metric, seed):
    """Shape/seed sweep: Pallas block decomposition == unblocked oracle."""
    rng = np.random.default_rng(seed)
    n = n_blocks * bn
    x = rand(rng, n, d, scale=5.0)
    c = rand(rng, k, d, scale=5.0)
    idx, mind = ak.assign(x, c, metric=metric, block_n=bn)
    _, rmind = ref.assign(x, c, metric=metric)
    assert_allclose(np.asarray(mind), np.asarray(rmind), rtol=3e-5, atol=1e-3)
    d_at = np.take_along_axis(
        np.asarray(ref.pair_dists(x, c, metric)), np.asarray(idx)[:, None], axis=1
    )[:, 0]
    assert_allclose(d_at, np.asarray(rmind), rtol=3e-5, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    j=st.sampled_from([16, 64]),
    d=st.integers(1, 16),
    k=st.integers(1, 9),
    metric=st.sampled_from(ref.METRICS),
    seed=st.integers(0, 2**31 - 1),
)
def test_pairdist_hypothesis_sweep(j, d, k, metric, seed):
    rng = np.random.default_rng(seed)
    mids = rand(rng, j, d, scale=3.0)
    cands = rand(rng, j, k, d, scale=3.0)
    got = ak.batched_pair_dists(mids, cands, metric=metric, block_j=j)
    want = ref.batched_pair_dists(mids, cands, metric=metric)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=1e-3)


def test_pairdist_blocked_equals_unblocked():
    rng = np.random.default_rng(5)
    mids = rand(rng, 128, 6)
    cands = rand(rng, 128, 4, 6)
    a = ak.batched_pair_dists(mids, cands, block_j=32)
    b = ak.batched_pair_dists(mids, cands, block_j=128)
    assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
