"""AOT pipeline integrity: the variant grid, the manifest contract the
Rust runtime depends on, and (when `make artifacts` has run) the integrity
of the emitted files."""

import hashlib
import json
import os

import pytest

from compile import aot

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART_DIR, "manifest.json")


def test_variant_grid_covers_paper_evaluation_space():
    """Every figure's (d, k) demand must fit some artifact after padding."""
    demands = [
        # (kind-list, d, k): Fig2 (D=3,K=8), Fig3a (15, up to 100),
        # Fig3b (up to 50, 6), headline (15, 20)
        (3, 8),
        (15, 100),
        (50, 6),
        (15, 20),
    ]
    lloyd = [(d, k) for (_, d, k) in aot.LLOYD_VARIANTS]
    filt = [(d, k) for (_, d, k) in aot.FILTER_VARIANTS]
    for (d, k) in demands:
        assert any(dd >= d and kk >= k for (dd, kk) in lloyd), f"lloyd gap at d={d} k={k}"
        assert any(dd >= d and kk >= k for (dd, kk) in filt), f"filter gap at d={d} k={k}"


def test_manhattan_variants_present():
    assert any(m == "manhattan" for (m, _, _) in aot.LLOYD_VARIANTS)
    assert any(m == "manhattan" for (m, _, _) in aot.FILTER_VARIANTS)


def test_block_sizes_match_kernel_tiling():
    assert aot.LLOYD_BLOCK_N % aot.LLOYD_TILE_N == 0
    for j in aot.FILTER_BLOCK_JS:
        assert j % aot.FILTER_TILE_J == 0


@pytest.mark.skipif(not os.path.exists(MANIFEST), reason="run `make artifacts` first")
def test_manifest_matches_emitted_files():
    with open(MANIFEST) as f:
        manifest = json.load(f)
    assert manifest["format_version"] == 1
    assert manifest["pad_sentinel"] == 1e17
    entries = manifest["entries"]
    assert len(entries) == len(aot.LLOYD_VARIANTS) + len(aot.FILTER_VARIANTS) * len(
        aot.FILTER_BLOCK_JS
    )
    for e in entries:
        path = os.path.join(ART_DIR, e["file"])
        assert os.path.exists(path), f"missing artifact {e['file']}"
        text = open(path).read()
        assert hashlib.sha256(text.encode()).hexdigest() == e["sha256"], (
            f"artifact {e['name']} drifted from its manifest hash — rerun `make artifacts`"
        )
        # HLO text sanity: entry computation + expected parameter count.
        assert "ENTRY" in text
        assert e["kind"] in ("lloyd", "filter")
        n_inputs = len(e["inputs"])
        assert n_inputs == (3 if e["kind"] == "lloyd" else 2)


@pytest.mark.skipif(not os.path.exists(MANIFEST), reason="run `make artifacts` first")
def test_manifest_shapes_are_consistent():
    with open(MANIFEST) as f:
        manifest = json.load(f)
    for e in manifest["entries"]:
        n, d, k = e["n"], e["d"], e["k"]
        if e["kind"] == "lloyd":
            assert e["inputs"][0]["shape"] == [n, d]
            assert e["inputs"][1]["shape"] == [k, d]
            assert e["inputs"][2]["shape"] == [n]
            assert e["outputs"][0]["shape"] == [n]
            assert e["outputs"][1]["shape"] == [k, d]
        else:
            assert e["inputs"][0]["shape"] == [n, d]
            assert e["inputs"][1]["shape"] == [n, k, d]
            assert e["outputs"][0]["shape"] == [n, k]
