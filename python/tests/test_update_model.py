"""Pallas update kernel + L2 lloyd_step / filter_dists vs the oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref
from compile.kernels import update as uk

jax.config.update("jax_platform_name", "cpu")


def rand(rng, *shape, scale=10.0):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


@pytest.mark.parametrize("n,d,k,bn", [(64, 3, 5, 16), (128, 8, 7, 64), (32, 2, 1, 32)])
def test_update_matches_ref(n, d, k, bn):
    rng = np.random.default_rng(2)
    x = rand(rng, n, d)
    idx = jnp.asarray(rng.integers(0, k, n).astype(np.int32))
    w = jnp.ones((n,), jnp.float32)
    sums, counts = uk.update(x, idx, w, k=k, block_n=bn)
    rsums, rcounts = ref.update(x, idx, w, k)
    assert_allclose(np.asarray(sums), np.asarray(rsums), rtol=1e-5, atol=1e-3)
    assert_allclose(np.asarray(counts), np.asarray(rcounts), rtol=0, atol=0)


def test_update_weights_mask_padding():
    """Zero-weight rows (block padding) must contribute nothing."""
    rng = np.random.default_rng(4)
    x = rand(rng, 64, 4)
    idx = jnp.asarray(rng.integers(0, 3, 64).astype(np.int32))
    w = jnp.concatenate([jnp.ones((40,), jnp.float32), jnp.zeros((24,), jnp.float32)])
    sums, counts = uk.update(x, idx, w, k=3, block_n=16)
    rsums, rcounts = ref.update(x[:40], idx[:40], jnp.ones((40,)), 3)
    assert_allclose(np.asarray(sums), np.asarray(rsums), rtol=1e-5, atol=1e-3)
    assert_allclose(np.asarray(counts), np.asarray(rcounts))


def test_update_accumulates_across_blocks():
    """Grid accumulation == single-block computation."""
    rng = np.random.default_rng(9)
    x = rand(rng, 128, 5)
    idx = jnp.asarray(rng.integers(0, 4, 128).astype(np.int32))
    w = jnp.ones((128,), jnp.float32)
    a = uk.update(x, idx, w, k=4, block_n=16)
    b = uk.update(x, idx, w, k=4, block_n=128)
    assert_allclose(np.asarray(a[0]), np.asarray(b[0]), rtol=1e-5, atol=1e-3)
    assert_allclose(np.asarray(a[1]), np.asarray(b[1]))


@settings(max_examples=20, deadline=None)
@given(
    n_blocks=st.integers(1, 4),
    bn=st.sampled_from([8, 32]),
    d=st.integers(1, 16),
    k=st.integers(1, 20),
    frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_update_hypothesis_sweep(n_blocks, bn, d, k, frac, seed):
    rng = np.random.default_rng(seed)
    n = n_blocks * bn
    x = rand(rng, n, d, scale=4.0)
    idx = jnp.asarray(rng.integers(0, k, n).astype(np.int32))
    w = jnp.asarray((rng.random(n) < frac).astype(np.float32))
    sums, counts = uk.update(x, idx, w, k=k, block_n=bn)
    rsums, rcounts = ref.update(x, idx, w, k)
    assert_allclose(np.asarray(sums), np.asarray(rsums), rtol=1e-4, atol=1e-2)
    assert_allclose(np.asarray(counts), np.asarray(rcounts))


# ---------------------------------------------------------------------------
# L2 model
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", ref.METRICS)
def test_lloyd_step_matches_ref(metric):
    rng = np.random.default_rng(13)
    x = rand(rng, 256, 8)
    c = rand(rng, 6, 8)
    w = jnp.ones((256,), jnp.float32)
    idx, sums, counts, cost = model.lloyd_step(x, c, w, metric=metric, block_n=64)
    ridx, rsums, rcounts, rcost = ref.lloyd_step(x, c, w, metric=metric)
    assert_allclose(np.asarray(sums), np.asarray(rsums), rtol=1e-4, atol=1e-2)
    assert_allclose(np.asarray(counts), np.asarray(rcounts))
    assert_allclose(float(cost[0]), float(rcost), rtol=1e-4)


def test_lloyd_step_padded_full_contract():
    """Exercise the exact padding contract the Rust runtime relies on:

    N padded with zero rows + zero weights, K padded with sentinel rows,
    D padded with zero columns. Valid-region outputs must equal the
    unpadded reference.
    """
    rng = np.random.default_rng(21)
    n, d, k = 100, 3, 5
    npad, dpad, kpad = 128, 4, 8
    x = rng.standard_normal((n, d)).astype(np.float32) * 2.0
    c = rng.standard_normal((k, d)).astype(np.float32) * 2.0

    xp = np.zeros((npad, dpad), np.float32)
    xp[:n, :d] = x
    cp = np.full((kpad, dpad), ref.PAD_SENTINEL, np.float32)
    cp[:k, :d] = c
    cp[:k, d:] = 0.0
    w = np.zeros((npad,), np.float32)
    w[:n] = 1.0

    idx, sums, counts, cost = model.lloyd_step(
        jnp.asarray(xp), jnp.asarray(cp), jnp.asarray(w), block_n=32
    )
    ridx, rsums, rcounts, rcost = ref.lloyd_step(jnp.asarray(x), jnp.asarray(c), jnp.ones((n,)))

    np.testing.assert_array_equal(np.asarray(idx)[:n], np.asarray(ridx))
    assert_allclose(np.asarray(sums)[:k, :d], np.asarray(rsums), rtol=1e-4, atol=1e-2)
    assert np.all(np.asarray(counts)[k:] == 0.0)
    assert_allclose(np.asarray(counts)[:k], np.asarray(rcounts))
    assert_allclose(float(cost[0]), float(rcost), rtol=1e-4)


def test_filter_dists_matches_ref():
    rng = np.random.default_rng(17)
    mids = rand(rng, 64, 6, scale=2.0)
    cands = rand(rng, 64, 5, 6, scale=2.0)
    got = model.filter_dists(mids, cands, block_j=16)
    want = ref.batched_pair_dists(mids, cands)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=1e-3)


def test_centroid_recovery_synthetic():
    """End-to-end sanity: iterated lloyd_step recovers planted centroids."""
    rng = np.random.default_rng(0)
    true_c = np.array([[0.0, 0.0], [10.0, 10.0], [-10.0, 10.0]], np.float32)
    pts = np.concatenate(
        [rng.standard_normal((256, 2)).astype(np.float32) * 0.5 + c for c in true_c]
    )
    rng.shuffle(pts)
    x = jnp.asarray(pts)
    w = jnp.ones((x.shape[0],), jnp.float32)
    c = jnp.asarray(pts[:3].copy())
    for _ in range(12):
        _, sums, counts, _ = model.lloyd_step(x, c, w, block_n=256)
        c = sums / jnp.maximum(counts[:, None], 1.0)
    got = np.sort(np.asarray(c), axis=0)
    want = np.sort(true_c, axis=0)
    assert_allclose(got, want, atol=0.2)
