"""AOT compile path: lower the L2 graph to HLO text for the Rust runtime.

Run once by ``make artifacts`` (never at request time):

    cd python && python -m compile.aot --out ../artifacts

Emits one ``<name>.hlo.txt`` per (entry, metric, shape) variant plus a
``manifest.json`` the Rust runtime (`rust/src/runtime/artifacts.rs`) uses to
pick the smallest variant a request fits into after padding.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's pinned xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# ---------------------------------------------------------------------------
# Variant grid.
#
# The Rust coordinator pads every request up to one of these shapes.  The
# grid covers the paper's evaluation space:
#   Fig 2  : D=3  -> (4, 8)        (Winterstein-style workloads, K=8)
#   Fig 3a : D=15, K=2..100 -> (16, 32) and (16, 128)
#   Fig 3b : D=2..50, K=6  -> (64, 8)
#   Table 1 / headline : K up to 20 -> (16, 32)
# ---------------------------------------------------------------------------

LLOYD_BLOCK_N = 1024  # points per PJRT call; kernel streams 256-point tiles
LLOYD_TILE_N = 256
# Filtering node-visit blocks per PJRT call: two sizes per (metric, d, k)
# so the runtime can pick the larger block for big tree levels (amortizing
# per-execution overhead ~4x) and the small one for shallow levels (less
# padding waste).  See §Perf L1-1 in EXPERIMENTS.md.
FILTER_BLOCK_JS = (256, 1024)
FILTER_TILE_J = 64

LLOYD_VARIANTS = [
    # (metric, D_pad, K_pad)
    ("euclid", 4, 8),
    ("euclid", 16, 32),
    ("euclid", 16, 128),
    ("euclid", 64, 8),
    ("manhattan", 4, 8),
    ("manhattan", 16, 32),
]

FILTER_VARIANTS = [
    ("euclid", 4, 8),
    ("euclid", 16, 32),
    ("euclid", 16, 128),
    ("euclid", 64, 8),
    ("manhattan", 16, 32),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_lloyd(metric: str, d: int, k: int):
    fn = functools.partial(model.lloyd_step, metric=metric, block_n=LLOYD_TILE_N)
    spec = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    return jax.jit(fn).lower(
        spec(LLOYD_BLOCK_N, d), spec(k, d), spec(LLOYD_BLOCK_N)
    )


def lower_filter(metric: str, d: int, k: int, block_j: int):
    fn = functools.partial(model.filter_dists, metric=metric, block_j=FILTER_TILE_J)
    spec = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    return jax.jit(fn).lower(spec(block_j, d), spec(block_j, k, d))


def build(out_dir: str, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []

    for metric, d, k in LLOYD_VARIANTS:
        name = f"lloyd_{metric}_n{LLOYD_BLOCK_N}_d{d}_k{k}"
        text = to_hlo_text(lower_lloyd(metric, d, k))
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entries.append(
            {
                "name": name,
                "kind": "lloyd",
                "metric": metric,
                "n": LLOYD_BLOCK_N,
                "d": d,
                "k": k,
                "file": os.path.basename(path),
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "inputs": [
                    {"shape": [LLOYD_BLOCK_N, d], "dtype": "f32"},
                    {"shape": [k, d], "dtype": "f32"},
                    {"shape": [LLOYD_BLOCK_N], "dtype": "f32"},
                ],
                "outputs": [
                    {"shape": [LLOYD_BLOCK_N], "dtype": "i32"},
                    {"shape": [k, d], "dtype": "f32"},
                    {"shape": [k], "dtype": "f32"},
                    {"shape": [1], "dtype": "f32"},
                ],
            }
        )
        if verbose:
            print(f"  wrote {path} ({len(text)} chars)")

    for metric, d, k in FILTER_VARIANTS:
        for block_j in FILTER_BLOCK_JS:
            name = f"filter_{metric}_j{block_j}_d{d}_k{k}"
            text = to_hlo_text(lower_filter(metric, d, k, block_j))
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            entries.append(
                {
                    "name": name,
                    "kind": "filter",
                    "metric": metric,
                    "n": block_j,
                    "d": d,
                    "k": k,
                    "file": os.path.basename(path),
                    "sha256": hashlib.sha256(text.encode()).hexdigest(),
                    "inputs": [
                        {"shape": [block_j, d], "dtype": "f32"},
                        {"shape": [block_j, k, d], "dtype": "f32"},
                    ],
                    "outputs": [{"shape": [block_j, k], "dtype": "f32"}],
                }
            )
            if verbose:
                print(f"  wrote {path} ({len(text)} chars)")

    manifest = {
        "format_version": 1,
        "jax_version": jax.__version__,
        "pad_sentinel": 1.0e17,
        "entries": entries,
    }
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    if verbose:
        print(f"  wrote {mpath} ({len(entries)} artifacts)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    args = ap.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
