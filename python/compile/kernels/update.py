"""L1 Pallas kernel: centroid update (the PL "updater" modules).

The paper's updater accumulates each point into its winning cluster's
weighted-centroid register bank.  On TPU the idiomatic formulation is a
one-hot matmul — ``onehot[N, K].T @ points[N, D]`` — which runs on the MXU
and keeps the whole update step in the same fused program as the
assignment.  The kernel walks point tiles on the grid and accumulates the
per-cluster partial sums/counts into a grid-invariant output tile
(revisited output block = accumulation, zero-initialised on the first grid
step), which is the Pallas analogue of the PL register bank surviving
across FIFO bursts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .assign import DEFAULT_BLOCK_N


def _update_kernel(x_ref, idx_ref, w_ref, sums_ref, counts_ref):
    step = pl.program_id(0)

    # Zero the accumulators on the first tile; they are grid-invariant
    # output blocks, so later steps see the running totals.
    @pl.when(step == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    x = x_ref[...]  # [BN, D]
    idx = idx_ref[...]  # [BN]
    w = w_ref[...]  # [BN]
    k = sums_ref.shape[0]
    onehot = (idx[:, None] == jnp.arange(k, dtype=jnp.int32)[None, :]).astype(jnp.float32)
    onehot = onehot * w[:, None]  # [BN, K]
    # MXU op: [K, BN] x [BN, D].
    sums_ref[...] += jnp.dot(onehot.T, x, preferred_element_type=jnp.float32)
    counts_ref[...] += jnp.sum(onehot, axis=0)


@functools.partial(jax.jit, static_argnames=("k", "block_n"))
def update(points, assignments, weights, k: int, block_n: int = DEFAULT_BLOCK_N):
    """Pallas update step: ``(sums f32[K, D], counts f32[K])``.

    ``assignments`` are the winners from :func:`kernels.assign.assign`;
    ``weights`` zero out block-padding rows so they contribute nothing.
    """
    n, d = points.shape
    bn = min(block_n, n)
    if n % bn != 0:
        raise ValueError(f"N={n} must be a multiple of block_n={bn}")
    grid = (n // bn,)
    return pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            # Grid-invariant accumulator tiles (the PL register bank).
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=True,
    )(points, assignments, weights)
