"""Pure-jnp reference oracle for the L1 Pallas kernels.

Every Pallas kernel in this package has a corresponding reference
implementation here, written with plain ``jax.numpy`` ops only, with no
blocking/tiling tricks.  ``python/tests`` asserts the Pallas outputs against
these references (``assert_allclose``), including over hypothesis-generated
shape/dtype sweeps, before anything is AOT-lowered for the Rust runtime.

Conventions shared with the kernels and the Rust coordinator:

- ``points``    f32[N, D]   point block (rows past the real count are padding)
- ``centroids`` f32[K, D]   centroid panel; padded rows use ``PAD_SENTINEL``
- ``weights``   f32[N]      1.0 for real rows, 0.0 for padding rows
- distances are *squared* Euclidean (``metric="euclid"``) or L1 Manhattan
  (``metric="manhattan"``) — the Rust side never takes a sqrt either.
"""

from __future__ import annotations

import jax.numpy as jnp

# Padded (invalid) centroid rows are filled with this value.  It is large
# enough that no real point can be closer to a padded centroid than to a real
# one, but small enough that the squared-distance expansion
# ``x^2 - 2xc + c^2`` stays finite in f32 (max ~3.4e38):  with D <= 64 and
# |x| <= 1e6, d2 <= 64 * (1e17)^2 ~= 6.4e35  <  f32 max.
PAD_SENTINEL = 1.0e17

#: Metrics understood by every kernel in this package.
METRICS = ("euclid", "manhattan")


def pair_dists(points, centroids, metric: str = "euclid"):
    """All-pairs distances ``f32[N, K]`` between points and centroids.

    ``euclid`` returns *squared* L2 distances (monotone in L2, so arg-min and
    filtering tests are unchanged and the PL never pays for a sqrt — the
    paper's fixed-point pipelines make the same move).
    """
    if metric == "euclid":
        # The MXU-friendly expansion used by the Pallas kernel as well.
        x2 = jnp.sum(points * points, axis=1, keepdims=True)  # [N, 1]
        c2 = jnp.sum(centroids * centroids, axis=1)[None, :]  # [1, K]
        xc = points @ centroids.T  # [N, K]
        d = x2 - 2.0 * xc + c2
        # The expansion can go slightly negative through cancellation.
        return jnp.maximum(d, 0.0)
    if metric == "manhattan":
        return jnp.sum(jnp.abs(points[:, None, :] - centroids[None, :, :]), axis=2)
    raise ValueError(f"unknown metric {metric!r}")


def assign(points, centroids, metric: str = "euclid"):
    """Assignment step: ``(assignments i32[N], min_dist f32[N])``."""
    d = pair_dists(points, centroids, metric)
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    return idx, jnp.min(d, axis=1)


def update(points, assignments, weights, k: int):
    """Update step: per-cluster weighted sums and counts.

    Returns ``(sums f32[K, D], counts f32[K])``.  Rows whose weight is zero
    (block padding) contribute nothing.
    """
    onehot = (assignments[:, None] == jnp.arange(k, dtype=jnp.int32)[None, :]).astype(jnp.float32)
    onehot = onehot * weights[:, None]  # [N, K]
    sums = onehot.T @ points  # [K, D]
    counts = jnp.sum(onehot, axis=0)  # [K]
    return sums, counts


def lloyd_step(points, centroids, weights, metric: str = "euclid"):
    """One full k-means (Lloyd) iteration over a point block.

    Returns ``(assignments i32[N], sums f32[K, D], counts f32[K], cost f32)``
    where ``cost`` is the weighted sum of min-distances (the k-means
    objective for this block, squared-L2 or L1 depending on ``metric``).
    """
    idx, mind = assign(points, centroids, metric)
    sums, counts = update(points, idx, weights, centroids.shape[0])
    cost = jnp.sum(mind * weights)
    return idx, sums, counts, cost


def batched_pair_dists(mids, cands, metric: str = "euclid"):
    """Filtering-offload oracle: per-job candidate distances.

    ``mids``  f32[J, D]    — one query point per job (a kd-cell midpoint)
    ``cands`` f32[J, K, D] — per-job candidate centroid panel (padded rows
                             use ``PAD_SENTINEL``)
    Returns ``f32[J, K]``.
    """
    if metric == "euclid":
        diff = mids[:, None, :] - cands  # [J, K, D]
        return jnp.sum(diff * diff, axis=2)
    if metric == "manhattan":
        return jnp.sum(jnp.abs(mids[:, None, :] - cands), axis=2)
    raise ValueError(f"unknown metric {metric!r}")
