"""L1 Pallas kernels: the "PL arithmetic cores" of MUCH-SWIFT.

The paper implements K x 4 parallel fixed-latency distance / compare /
update pipelines in FPGA programmable logic, fed from a BRAM FIFO that
double-buffers DDR3 bursts.  The TPU re-think (DESIGN.md
section "Hardware-Adaptation"):

- the *assignment* hot loop becomes a ``[BLOCK_N, D] x [D, K]`` matmul on the
  MXU via the squared-distance expansion ``x^2 - 2 x.c + c^2`` (euclid), or a
  VPU broadcast/abs/reduce sweep (manhattan — the metric the paper's PL
  actually wires up, which has no matmul form);
- the BRAM double-buffer becomes the ``BlockSpec`` HBM->VMEM schedule: the
  grid walks ``BLOCK_N``-point tiles while the ``[K, D]`` centroid panel
  stays VMEM-resident across the whole grid (same reuse the paper gets from
  holding centroids in PL registers);
- the paper's log2(K) comparator tree becomes a lane-wise arg-min.

All kernels run under ``interpret=True``: the CPU PJRT plugin used by the
Rust runtime cannot execute Mosaic custom-calls, so interpret mode is the
correctness path and real-TPU performance is estimated analytically in
EXPERIMENTS.md from the VMEM footprint / MXU utilization of these BlockSpecs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default tile of points streamed HBM->VMEM per grid step.  1024 x 64 dims x
# 4 B = 256 KiB worst case, which together with the centroid panel
# (128 x 64 x 4 B = 32 KiB) and the [BLOCK_N, K] distance tile
# (1024 x 128 x 4 B = 512 KiB) fits comfortably in a 16 MiB TPU VMEM with
# room for double buffering.
DEFAULT_BLOCK_N = 1024


def _assign_euclid_kernel(x_ref, c_ref, idx_ref, dist_ref):
    """Squared-L2 assignment over one point tile (MXU formulation)."""
    x = x_ref[...]  # [BN, D]
    c = c_ref[...]  # [K, D]
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # [BN, 1]
    c2 = jnp.sum(c * c, axis=1)[None, :]  # [1, K]
    # The MXU op: everything else in this kernel is elementwise VPU work.
    xc = jnp.dot(x, c.T, preferred_element_type=jnp.float32)  # [BN, K]
    d2 = jnp.maximum(x2 - 2.0 * xc + c2, 0.0)
    idx_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)
    dist_ref[...] = jnp.min(d2, axis=1)


def _assign_manhattan_kernel(x_ref, c_ref, idx_ref, dist_ref):
    """L1 assignment over one point tile (VPU formulation).

    Manhattan distance has no matmul form, so this kernel mirrors the
    paper's PL pipeline directly: stream the K centroids through a
    subtract/abs/accumulate datapath and keep a running (best_dist, best_idx)
    pair — the comparator tree collapsed into a sequential scan, which the
    VPU executes one full [BN, D] lane-tile per step.
    """
    x = x_ref[...]  # [BN, D]
    c = c_ref[...]  # [K, D]
    k = c.shape[0]
    bn = x.shape[0]

    def body(j, carry):
        best_d, best_i = carry
        d = jnp.sum(jnp.abs(x - c[j][None, :]), axis=1)  # [BN]
        better = d < best_d
        return (
            jnp.where(better, d, best_d),
            jnp.where(better, jnp.int32(j), best_i),
        )

    init = (jnp.full((bn,), jnp.inf, jnp.float32), jnp.zeros((bn,), jnp.int32))
    best_d, best_i = jax.lax.fori_loop(0, k, body, init)
    idx_ref[...] = best_i
    dist_ref[...] = best_d


_KERNELS = {
    "euclid": _assign_euclid_kernel,
    "manhattan": _assign_manhattan_kernel,
}


@functools.partial(jax.jit, static_argnames=("metric", "block_n"))
def assign(points, centroids, metric: str = "euclid", block_n: int = DEFAULT_BLOCK_N):
    """Pallas assignment step: ``(assignments i32[N], min_dist f32[N])``.

    ``points`` is ``f32[N, D]`` with ``N % block_n == 0`` (the Rust
    coordinator always ships full blocks, padding the tail with
    zero-weighted rows); ``centroids`` is ``f32[K, D]`` with padded rows set
    to ``ref.PAD_SENTINEL``.
    """
    n, d = points.shape
    k = centroids.shape[0]
    bn = min(block_n, n)
    if n % bn != 0:
        raise ValueError(f"N={n} must be a multiple of block_n={bn}")
    grid = (n // bn,)
    return pl.pallas_call(
        _KERNELS[metric],
        grid=grid,
        in_specs=[
            # point tiles stream; the centroid panel is grid-invariant
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(points, centroids)


def _pairdist_euclid_kernel(m_ref, c_ref, d_ref):
    m = m_ref[...]  # [BJ, D]
    c = c_ref[...]  # [BJ, K, D]
    diff = m[:, None, :] - c
    d_ref[...] = jnp.sum(diff * diff, axis=2)


def _pairdist_manhattan_kernel(m_ref, c_ref, d_ref):
    m = m_ref[...]
    c = c_ref[...]
    d_ref[...] = jnp.sum(jnp.abs(m[:, None, :] - c), axis=2)


_PAIRDIST_KERNELS = {
    "euclid": _pairdist_euclid_kernel,
    "manhattan": _pairdist_manhattan_kernel,
}


@functools.partial(jax.jit, static_argnames=("metric", "block_j"))
def batched_pair_dists(mids, cands, metric: str = "euclid", block_j: int = 256):
    """Filtering-offload kernel: per-job candidate distance panels.

    One "job" is one kd-tree node visit from Alg. 1: ``mids[j]`` is the
    node's cell midpoint (or leaf point) and ``cands[j]`` its candidate
    centroid set, padded to K with ``ref.PAD_SENTINEL`` rows.  The Rust
    coordinator batches all visits of one tree level into a single call —
    the same level-by-level schedule the paper uses to size its BRAM bridge
    (section 4.2).  Returns ``f32[J, K]``.
    """
    j, d = mids.shape
    _, k, _ = cands.shape
    bj = min(block_j, j)
    if j % bj != 0:
        raise ValueError(f"J={j} must be a multiple of block_j={bj}")
    grid = (j // bj,)
    return pl.pallas_call(
        _PAIRDIST_KERNELS[metric],
        grid=grid,
        in_specs=[
            pl.BlockSpec((bj, d), lambda i: (i, 0)),
            pl.BlockSpec((bj, k, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bj, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((j, k), jnp.float32),
        interpret=True,
    )(mids, cands)
