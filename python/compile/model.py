"""L2: the JAX compute graph the Rust coordinator executes via PJRT.

Two entry points, both AOT-lowered to HLO text by ``aot.py``:

- :func:`lloyd_step` — one full k-means iteration over a padded point
  block; assignment + compare run in the L1 Pallas kernels
  (``kernels.assign``), the centroid update in ``kernels.update``.  This is
  the work the paper offloads to the PL for the plain-Lloyd baselines and
  for the first-level clustering bursts.
- :func:`filter_dists` — the per-tree-level distance panels the filtering
  algorithm (Alg. 1) needs; the tree logic itself stays on the "PS" (the
  Rust coordinator), exactly like the paper keeps traversal on the A53s and
  only ships arithmetic to the PL.

Shapes are static per artifact (PJRT has no dynamic shapes): the Rust side
pads N up with zero-weight rows, D up with zero columns and K up with
``PAD_SENTINEL`` centroid rows, then slices the valid prefix out of the
results.  The (D, K) variant grid lives in ``aot.py``.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import assign as assign_kernels
from .kernels import update as update_kernels


def lloyd_step(points, centroids, weights, metric: str = "euclid", block_n: int | None = None):
    """One k-means iteration over a block.

    Args:
      points:    f32[N, D]  (N a multiple of the kernel block; pad rows with
                 zeros and give them weight 0)
      centroids: f32[K, D]  (pad rows with ``PAD_SENTINEL``)
      weights:   f32[N]     (1 = real row, 0 = padding)
      metric:    "euclid" (squared L2) or "manhattan" (L1)

    Returns:
      assignments i32[N], sums f32[K, D], counts f32[K], cost f32[1]
      — the caller (Rust) divides sums by counts to get the new centroids,
      which keeps the cross-block reduction (4 workers x many blocks) on the
      coordinator where the paper's R5 core does it.
    """
    kwargs = {} if block_n is None else {"block_n": block_n}
    idx, mind = assign_kernels.assign(points, centroids, metric=metric, **kwargs)
    sums, counts = update_kernels.update(points, idx, weights, k=centroids.shape[0], **kwargs)
    cost = jnp.sum(mind * weights)[None]
    return idx, sums, counts, cost


def filter_dists(mids, cands, metric: str = "euclid", block_j: int | None = None):
    """Distance panels for a batch of filtering-algorithm node visits.

    Args:
      mids:  f32[J, D]    cell midpoints (or leaf points)
      cands: f32[J, K, D] per-job candidate panels, ``PAD_SENTINEL``-padded

    Returns:
      dists f32[J, K] — the Rust side does the arg-min *and* the
      ``z.isFarther(z*, C)`` bounding-box pruning test, which needs the cell
      geometry that never leaves the PS.
    """
    kwargs = {} if block_j is None else {"block_j": block_j}
    return assign_kernels.batched_pair_dists(mids, cands, metric=metric, **kwargs)
