//! Minimal offline subset of the `anyhow` crate (see crates/README.md).
//!
//! Provides the surface the workspace uses: [`Error`] (an opaque boxed
//! error), [`Result`], and the [`anyhow!`] / [`bail!`] / [`ensure!`]
//! macros.  Like the real crate, `Error` deliberately does **not**
//! implement `std::error::Error`, which is what allows the blanket
//! `From<E: std::error::Error>` conversion to coexist with the identity
//! `From<Error>` used by `?`.

use std::error::Error as StdError;
use std::fmt;

/// An opaque error: any `std::error::Error + Send + Sync` boxed up, or an
/// ad-hoc message built by [`anyhow!`].
pub struct Error(Box<dyn StdError + Send + Sync + 'static>);

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M>(message: M) -> Self
    where
        M: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        Error(Box::new(MessageError(message)))
    }

    /// Borrow the underlying boxed error.
    pub fn as_dyn(&self) -> &(dyn StdError + 'static) {
        &*self.0
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Debug prints the human-readable message (+ source chain), which
        // is what `expect`/`unwrap` surface in a panic.
        write!(f, "{}", self.0)?;
        let mut source = self.0.source();
        while let Some(s) = source {
            write!(f, "\n\ncaused by: {s}")?;
            source = s.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error(Box::new(e))
    }
}

/// `anyhow::Result<T>`: `std::result::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Message-only error payload behind [`Error::msg`].
struct MessageError<M>(M);

impl<M: fmt::Display> fmt::Display for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl<M: fmt::Debug> fmt::Debug for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&self.0, f)
    }
}

impl<M: fmt::Display + fmt::Debug> StdError for MessageError<M> {}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn macros_and_conversions() {
        let e = anyhow!("x = {}", 7);
        assert_eq!(format!("{e}"), "x = 7");
        assert!(fails().is_err());

        // `?` converts std errors.
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(io().is_err());

        // ensure! passes and fails.
        fn check(v: usize) -> Result<usize> {
            ensure!(v < 10, "v too big: {v}");
            Ok(v)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(30).is_err());
    }

    #[test]
    fn identity_question_mark() {
        fn outer() -> Result<()> {
            fails()?;
            Ok(())
        }
        let msg = format!("{}", outer().unwrap_err());
        assert_eq!(msg, "boom 42");
    }
}
