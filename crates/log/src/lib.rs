//! Minimal offline subset of the `log` facade (see crates/README.md).
//!
//! API-compatible (for the surface this workspace uses) with the real
//! crate: a global boxed logger, a global max-level filter, `Metadata` /
//! `Record` views, and the five level macros.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of one log record (most to least severe).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Max-level filter; `Off` disables everything.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of one record: level + target (module path).
#[derive(Clone, Copy, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata + preformatted arguments.
#[derive(Debug)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<Box<dyn Log>> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0); // Off until a logger installs

/// Returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger; fails if one is already set.
pub fn set_boxed_logger(logger: Box<dyn Log>) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global max level.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global max level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing — not public API.
#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    if !(level <= max_level()) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record {
            metadata: Metadata { level, target },
            args,
        };
        if logger.enabled(record.metadata()) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;

    impl Log for Counter {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= max_level()
        }
        fn log(&self, record: &Record) {
            if self.enabled(record.metadata()) {
                HITS.fetch_add(1, Ordering::Relaxed);
            }
        }
        fn flush(&self) {}
    }

    #[test]
    fn level_ordering_and_filtering() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(!(Level::Debug <= LevelFilter::Info));

        let _ = set_boxed_logger(Box::new(Counter));
        set_max_level(LevelFilter::Info);
        let before = HITS.load(Ordering::Relaxed);
        info!("hello {}", 1);
        debug!("filtered out");
        assert_eq!(HITS.load(Ordering::Relaxed), before + 1);
    }
}
