//! Typed stub of the PJRT binding surface `muchswift::runtime` consumes
//! (see crates/README.md).
//!
//! The offline build environment has no XLA/PJRT shared library, so this
//! crate provides the exact type/method surface `runtime::client` calls —
//! enough for the whole workspace (including `Backend::Pjrt` plumbing) to
//! compile and for CPU-backed paths to run end to end.  Every fallible
//! entry point fails with a clear, actionable message; because artifact
//! loading is the first PJRT touchpoint, callers see the failure at
//! `PjrtRuntime::load` and fall back (or skip) exactly as they do when
//! `make artifacts` has not been run.
//!
//! Swapping this path dependency for a real PJRT binding requires no
//! changes elsewhere in the workspace.

use std::fmt;

/// Stub error: always "backend not available".
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT backend not available in this build (offline `xla` stub — \
         see crates/README.md; use the CPU backend instead)"
    ))
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    /// The real binding opens the CPU PJRT plugin; the stub fails fast.
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> &'static str {
        "stub"
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Host literal (stub).  Shape-only construction succeeds so padding code
/// type-checks; anything that would need real device data fails.
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_tuple4(&self) -> Result<(Literal, Literal, Literal, Literal)> {
        Err(unavailable("Literal::to_tuple4"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_with_actionable_message() {
        let err = PjRtClient::cpu().map(|_| ()).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("PJRT backend not available"), "{msg}");
        assert!(msg.contains("crates/README.md"), "{msg}");
    }

    #[test]
    fn literal_shape_ops_succeed() {
        let l = Literal::vec1(&[1.0, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
    }
}
